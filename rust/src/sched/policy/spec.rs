//! Declarative policy specifications.
//!
//! A [`PolicySpec`] names a scheduling pipeline — (admission, shaper,
//! composer) — or an adaptive policy, in a form that parses from a preset
//! name, a compact `key=value` string, or JSON (via the vendored
//! `util::json` parser; no external crates offline), and compiles into the
//! existing [`Scheduler`] trait object via [`PolicySpec::build`] /
//! [`crate::sched::build`]. The five legacy [`Policy`] presets are
//! canonical compositions ([`PolicySpec::preset`]) and the per-policy
//! default constants live HERE — [`SchedulerConfig::preset`] and the CLI
//! defaults read them, so presets cannot drift from their `--policy-spec`
//! equivalents.
//!
//! Accepted forms (see [`PolicySpec::parse`]):
//!
//! * preset names — `static | orca | chunked | layered | hybrid`
//!   (case-insensitive, plus the `continuous` / `sarathi` aliases);
//! * `adaptive` or `adaptive:long=1024,window=10,tbt=0.03,chunk=512,`
//!   `target=512,bias=1.25,max-batch=256` — the signal-driven policy;
//! * compact pipelines — `admission=cohort:512,shaper=chunks:512,`
//!   `composer=groups:512` (omitted stages default to the chunked
//!   baseline's stage), optionally `name=my-spec`; the admission axis
//!   also accepts the size-aware `srpf[:max]` / `srpt[:max]` forms, and
//!   two orthogonal wrappers compose around any admission stage:
//!   `fairness=vtfq[,weights=1:4+2:1]` (cross-tenant virtual-time fair
//!   queueing) and `preemption=pause[:budget]` (priority preemption —
//!   pause outranked in-flight prefills for at most `budget` unit
//!   boundaries each; `preemption=none` is the default);
//! * JSON — `{"admission":{"kind":"fcfs","max_batch":256},`
//!   `"shaper":{"kind":"chunks","chunk":512},`
//!   `"composer":{"kind":"interleave"}}`, or `{"kind":"adaptive",...}`;
//!   [`PolicySpec::to_json`] round-trips.

use crate::config::{Policy, SchedulerConfig};
use crate::sched::policy::adaptive::AdaptiveScheduler;
use crate::sched::policy::stages::{
    BatchAdmission, CohortAdmission, CohortShaper, FullPromptShaper, GreedyAdmission,
    InterleaveComposer, LayerGroupComposer, SizedAdmission, SoloAdmission, SoloChunkShaper,
    TokenChunkShaper,
};
use crate::sched::policy::{AdmissionPolicy, BatchComposer, PipelineScheduler, PrefillShaper};
use crate::sched::Scheduler;
use crate::util::json::{self, Json};

use std::collections::BTreeMap;

/// Token-axis chunk size (Sarathi: typically 256–512; paper uses 512).
pub const CHUNK_TOKENS: u32 = 512;
/// Layer-axis per-iteration prefill work target: G(L) = ceil(L / target)
/// (paper §4.4 uses 512 to mirror the chunked baseline).
pub const GROUP_TOKEN_TARGET: u32 = 512;
/// Hybrid (§4.3) token-axis chunk applied before layering (large, so MoE
/// expert GEMMs stay compute-bound).
pub const HYBRID_CHUNK_TOKENS: u32 = 4096;
/// Max concurrent requests in the running batch.
pub const MAX_BATCH: usize = 256;
/// Static batching batch size.
pub const STATIC_BATCH: usize = 16;

/// Stage 1 spec: who enters the running batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionSpec {
    /// Greedy FCFS while the batch cap and KV allow (chunked / Orca).
    Fcfs { max_batch: usize },
    /// Fixed batches, run-to-completion (static batching).
    Batch { batch_size: usize },
    /// Merged admission cohorts, one cohort at a time (layered, §4.4).
    Cohort {
        max_batch: usize,
        merge: bool,
        merge_target: u32,
    },
    /// One request at a time; the next admits only when no admitted
    /// request has prefill remaining (hybrid, §4.3).
    Solo { max_batch: usize },
    /// Shortest-remaining-prefill-first: the waiting queue is reordered by
    /// (priority desc, remaining prefill asc, FCFS) before greedy
    /// admission.
    Srpf { max_batch: usize },
    /// SRPT: like [`AdmissionSpec::Srpf`] but the size key adds the
    /// declared output length (shortest remaining processing time).
    Srpt { max_batch: usize },
}

/// Stage 2 spec: how remaining prefill is sliced into units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShaperSpec {
    /// Token-axis budget chunks coalesced FCFS (Sarathi).
    TokenChunks { chunk: u32 },
    /// Whole remaining prompt per request (Orca / static).
    FullPrompt,
    /// The admission cohort's full remaining prefill as one unit (layered).
    CohortUnit,
    /// One request's next large chunk per unit (hybrid).
    SoloChunk { chunk: u32 },
}

/// Stage 3 spec: how prefill interleaves with decode across layer groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComposerSpec {
    /// One full-stack hybrid batch per iteration (token axis).
    Interleave,
    /// G(L) contiguous layer groups, one prefilling per iteration
    /// (layer axis, the paper's contribution).
    LayerGroups { target: u32 },
}

/// Cross-tenant fairness wrapper applied around the admission stage
/// (orthogonal to the admission/shaper/composer axes: any pipeline can
/// run with or without it).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum FairnessSpec {
    /// No reordering: admission sees the waiting queue in arrival order.
    #[default]
    None,
    /// Virtual-time (start-time) fair queueing over waiting requests —
    /// [`crate::tenant::FairQueue`]. `weights` overrides per-tenant
    /// weights (`(tenant, weight)` pairs); tenants absent here fall back
    /// to the session's [`crate::tenant::TenantRegistry`], then 1.
    Vtfq { weights: Vec<(u32, u32)> },
}

/// Priority-preemption wrapper applied around the admission stage —
/// outermost, outside any fairness wrapper — so it composes with every
/// admission/shaper/composer triple (and with `fairness=vtfq`) unchanged.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PreemptionSpec {
    /// No preemption: admitted prefills run to completion (the default —
    /// feature-off pipelines behave byte-identically to pre-preemption
    /// builds).
    #[default]
    None,
    /// Pause in-flight prefills outranked by a strictly-higher-priority
    /// waiting request ([`crate::sched::policy::preempt::PreemptingAdmission`]).
    /// `max_pauses` bounds the unit boundaries a request may spend paused
    /// over its lifetime (min 1), guaranteeing no starvation.
    Pause { max_pauses: u32 },
}

/// Knobs for the signal-driven adaptive policy (see
/// [`crate::sched::policy::adaptive`]). Per admission cohort it chooses
/// the token axis (chunked shaping) or the layer axis (full-remaining
/// unit over G groups) from live signals.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveSpec {
    /// Batch cap for the greedy cohort admission.
    pub max_batch: usize,
    /// Token-arm chunk size.
    pub chunk: u32,
    /// Layer-arm G(L) target.
    pub group_target: u32,
    /// Cohorts with at least this much remaining prefill are candidates
    /// for the layer axis (below it a prompt fits one chunk and chunking
    /// cannot amplify expert reloads).
    pub long_prompt: u32,
    /// Choose the layer axis when the modeled token-axis expert-load bytes
    /// exceed `reload_bias` × the layer-axis bytes (moe::traffic coverage
    /// estimate over the cohort's remaining prefill).
    pub reload_bias: f64,
    /// Sliding window (engine seconds) for the observed TTFT/TBT signals.
    pub window_s: f64,
    /// When > 0: observed windowed max TBT above this biases the choice
    /// toward the layer axis (smaller per-iteration prefill footprint).
    /// 0 disables the latency signal.
    pub tbt_slo_s: f64,
}

impl Default for AdaptiveSpec {
    fn default() -> Self {
        AdaptiveSpec {
            max_batch: MAX_BATCH,
            chunk: CHUNK_TOKENS,
            group_target: GROUP_TOKEN_TARGET,
            long_prompt: 2 * GROUP_TOKEN_TARGET,
            reload_bias: 1.25,
            window_s: 10.0,
            tbt_slo_s: 0.0,
        }
    }
}

/// A declarative scheduling policy: a named pipeline composition or the
/// adaptive policy. See the [module docs](self) for the accepted textual
/// forms.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    Pipeline {
        /// Optional display name (surfaced in reports; presets and
        /// unnamed compositions derive one).
        name: Option<String>,
        admission: AdmissionSpec,
        shaper: ShaperSpec,
        composer: ComposerSpec,
        /// Cross-tenant fairness wrapper around the admission stage.
        fairness: FairnessSpec,
        /// Priority-preemption wrapper around the admission stage
        /// (outermost; composes with fairness).
        preemption: PreemptionSpec,
    },
    Adaptive(AdaptiveSpec),
}

impl PolicySpec {
    /// The canonical composition of a legacy [`Policy`] preset —
    /// bit-identical to the direct construction (locked by
    /// `tests/policy_spec.rs`).
    pub fn preset(policy: Policy) -> PolicySpec {
        Self::from_config(&SchedulerConfig::preset(policy))
    }

    /// Re-express ANY legacy scheduler configuration (policy + knobs) as
    /// its canonical pipeline composition.
    pub fn from_config(cfg: &SchedulerConfig) -> PolicySpec {
        let (admission, shaper, composer) = match cfg.policy {
            Policy::Static => (
                AdmissionSpec::Batch {
                    batch_size: cfg.static_batch,
                },
                ShaperSpec::FullPrompt,
                ComposerSpec::Interleave,
            ),
            Policy::Orca => (
                AdmissionSpec::Fcfs {
                    max_batch: cfg.max_batch,
                },
                ShaperSpec::FullPrompt,
                ComposerSpec::Interleave,
            ),
            Policy::Chunked => (
                AdmissionSpec::Fcfs {
                    max_batch: cfg.max_batch,
                },
                ShaperSpec::TokenChunks {
                    chunk: cfg.chunk_size,
                },
                ComposerSpec::Interleave,
            ),
            Policy::Layered => (
                AdmissionSpec::Cohort {
                    max_batch: cfg.max_batch,
                    merge: cfg.merge_small_prefills,
                    merge_target: cfg.group_token_target,
                },
                ShaperSpec::CohortUnit,
                ComposerSpec::LayerGroups {
                    target: cfg.group_token_target,
                },
            ),
            Policy::Hybrid => (
                AdmissionSpec::Solo {
                    max_batch: cfg.max_batch,
                },
                ShaperSpec::SoloChunk {
                    chunk: cfg.hybrid_chunk_size,
                },
                ComposerSpec::LayerGroups {
                    target: cfg.group_token_target,
                },
            ),
        };
        PolicySpec::Pipeline {
            name: None,
            admission,
            shaper,
            composer,
            fairness: FairnessSpec::None,
            preemption: PreemptionSpec::None,
        }
    }

    /// The preset this composition IS, if any (component-wise equality
    /// with [`PolicySpec::preset`], names ignored). A fairness or
    /// preemption wrapper disqualifies: presets carry neither.
    pub fn matches_preset(&self) -> Option<Policy> {
        let PolicySpec::Pipeline {
            admission,
            shaper,
            composer,
            fairness,
            preemption,
            ..
        } = self
        else {
            return None;
        };
        if *fairness != FairnessSpec::None || *preemption != PreemptionSpec::None {
            return None;
        }
        for p in Policy::ALL {
            if let PolicySpec::Pipeline {
                admission: a,
                shaper: s,
                composer: c,
                ..
            } = PolicySpec::preset(p)
            {
                if *admission == a && *shaper == s && *composer == c {
                    return Some(p);
                }
            }
        }
        None
    }

    /// The legacy policy this spec is closest to — used where a coarse
    /// axis classification is needed (e.g. the SLO-aware router's
    /// layer-axis/token-axis split via `ReplicaView::policy`). Exact
    /// preset compositions map to their preset; otherwise the composer
    /// axis decides, and the adaptive policy counts as layer-capable.
    pub fn nearest_policy(&self) -> Policy {
        if let Some(p) = self.matches_preset() {
            return p;
        }
        match self {
            PolicySpec::Adaptive(_) => Policy::Layered,
            PolicySpec::Pipeline { composer, .. } => match composer {
                ComposerSpec::LayerGroups { .. } => Policy::Layered,
                ComposerSpec::Interleave => Policy::Chunked,
            },
        }
    }

    /// Display name: an explicit `name`, a preset's legacy name, or a
    /// derived `pipeline(..)` / `adaptive` label. Surfaced per replica in
    /// `SessionReport::policies` and the CLI tables.
    pub fn name(&self) -> String {
        match self {
            PolicySpec::Adaptive(_) => "adaptive".to_string(),
            PolicySpec::Pipeline {
                name: Some(n), ..
            } => n.clone(),
            PolicySpec::Pipeline {
                admission,
                shaper,
                composer,
                fairness,
                preemption,
                ..
            } => match self.matches_preset() {
                Some(p) => p.name().to_string(),
                None => {
                    let vtfq = match fairness {
                        FairnessSpec::None => "",
                        FairnessSpec::Vtfq { .. } => "+vtfq",
                    };
                    let preempt = match preemption {
                        PreemptionSpec::None => "",
                        PreemptionSpec::Pause { .. } => "+preempt",
                    };
                    format!(
                        "pipeline({}+{}+{}){vtfq}{preempt}",
                        admission_label(admission),
                        shaper_label(shaper),
                        composer_label(composer)
                    )
                }
            },
        }
    }

    /// Compile the spec into a scheduler for an `n_layers`-deep model.
    pub fn build(&self, n_layers: u32) -> Box<dyn Scheduler> {
        match self {
            PolicySpec::Adaptive(a) => Box::new(AdaptiveScheduler::new(*a, n_layers)),
            PolicySpec::Pipeline {
                admission,
                shaper,
                composer,
                fairness,
                preemption,
                ..
            } => {
                let admission: Box<dyn AdmissionPolicy> = match *admission {
                    AdmissionSpec::Fcfs { max_batch } => Box::new(GreedyAdmission::new(max_batch)),
                    AdmissionSpec::Batch { batch_size } => Box::new(BatchAdmission::new(batch_size)),
                    AdmissionSpec::Cohort {
                        max_batch,
                        merge,
                        merge_target,
                    } => Box::new(CohortAdmission::new(max_batch, merge, merge_target)),
                    AdmissionSpec::Solo { max_batch } => Box::new(SoloAdmission::new(max_batch)),
                    AdmissionSpec::Srpf { max_batch } => Box::new(SizedAdmission::srpf(max_batch)),
                    AdmissionSpec::Srpt { max_batch } => Box::new(SizedAdmission::srpt(max_batch)),
                };
                // The fairness wrapper composes around ANY admission
                // stage — vtfq reorders waiting, the inner policy admits.
                let admission: Box<dyn AdmissionPolicy> = match fairness {
                    FairnessSpec::None => admission,
                    FairnessSpec::Vtfq { weights } => {
                        Box::new(crate::tenant::FairQueue::new(admission, weights.clone()))
                    }
                };
                // Preemption wraps OUTERMOST: it pauses/resumes around
                // whatever the (possibly fairness-wrapped) stage admits.
                let admission: Box<dyn AdmissionPolicy> = match *preemption {
                    PreemptionSpec::None => admission,
                    PreemptionSpec::Pause { max_pauses } => Box::new(
                        crate::sched::policy::preempt::PreemptingAdmission::new(
                            admission, max_pauses,
                        ),
                    ),
                };
                let shaper: Box<dyn PrefillShaper> = match *shaper {
                    ShaperSpec::TokenChunks { chunk } => Box::new(TokenChunkShaper::new(chunk)),
                    ShaperSpec::FullPrompt => Box::new(FullPromptShaper::new()),
                    ShaperSpec::CohortUnit => Box::new(CohortShaper::new()),
                    ShaperSpec::SoloChunk { chunk } => Box::new(SoloChunkShaper::new(chunk)),
                };
                let composer: Box<dyn BatchComposer> = match *composer {
                    ComposerSpec::Interleave => Box::new(InterleaveComposer::new(n_layers)),
                    ComposerSpec::LayerGroups { target } => {
                        Box::new(LayerGroupComposer::new(n_layers, target))
                    }
                };
                Box::new(PipelineScheduler::new(
                    self.name(),
                    admission,
                    shaper,
                    composer,
                ))
            }
        }
    }

    /// A [`SchedulerConfig`] that carries this spec (so
    /// [`crate::sched::build`] compiles it) with the legacy knob fields
    /// mirrored for consumers that read them (replica views, KV sizing).
    pub fn scheduler_config(&self) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::preset(self.nearest_policy());
        match self {
            PolicySpec::Adaptive(a) => {
                cfg.max_batch = a.max_batch;
                cfg.chunk_size = a.chunk;
                cfg.group_token_target = a.group_target;
            }
            PolicySpec::Pipeline {
                admission,
                shaper,
                composer,
                ..
            } => {
                match *admission {
                    AdmissionSpec::Fcfs { max_batch }
                    | AdmissionSpec::Solo { max_batch }
                    | AdmissionSpec::Srpf { max_batch }
                    | AdmissionSpec::Srpt { max_batch } => cfg.max_batch = max_batch,
                    AdmissionSpec::Batch { batch_size } => cfg.static_batch = batch_size,
                    AdmissionSpec::Cohort {
                        max_batch,
                        merge,
                        merge_target,
                    } => {
                        cfg.max_batch = max_batch;
                        cfg.merge_small_prefills = merge;
                        cfg.group_token_target = merge_target;
                    }
                }
                match *shaper {
                    ShaperSpec::TokenChunks { chunk } => cfg.chunk_size = chunk,
                    ShaperSpec::SoloChunk { chunk } => cfg.hybrid_chunk_size = chunk,
                    ShaperSpec::FullPrompt | ShaperSpec::CohortUnit => {}
                }
                if let ComposerSpec::LayerGroups { target } = *composer {
                    cfg.group_token_target = target;
                }
            }
        }
        cfg.spec = Some(self.clone());
        cfg
    }

    /// Parse any accepted textual form: preset name, `adaptive[:knobs]`,
    /// compact `key=value` pipeline, or JSON (leading `{`). Errors name
    /// the valid alternatives.
    pub fn parse(s: &str) -> Result<PolicySpec, String> {
        let t = s.trim();
        if t.is_empty() {
            return Err("empty policy spec".to_string());
        }
        if t.starts_with('{') {
            let j = json::parse(t).map_err(|e| format!("policy spec JSON: {e}"))?;
            return Self::from_json(&j);
        }
        let lower = t.to_ascii_lowercase();
        if let Ok(p) = Policy::parse(&lower) {
            return Ok(Self::preset(p));
        }
        if lower == "adaptive" {
            return Ok(PolicySpec::Adaptive(AdaptiveSpec::default()));
        }
        if let Some(rest) = lower.strip_prefix("adaptive:") {
            return parse_adaptive_knobs(rest).map(PolicySpec::Adaptive);
        }
        if t.contains('=') {
            // Original-case text: keys and stage values are lowercased
            // per element, but a `name=` value keeps the user's spelling.
            return parse_compact(t);
        }
        Err(format!(
            "unknown policy spec '{t}' — want a preset (static | orca | chunked | layered | \
             hybrid), 'adaptive[:key=value,..]', a pipeline 'admission=..,shaper=..,composer=..', \
             or JSON"
        ))
    }

    /// Parse the JSON object form (see the module docs for the schema).
    pub fn from_json(j: &Json) -> Result<PolicySpec, String> {
        let kind = j.get("kind").and_then(Json::as_str);
        if kind == Some("adaptive") || (kind.is_none() && j.get("long_prompt").is_some()) {
            let d = AdaptiveSpec::default();
            let f = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
            return Ok(PolicySpec::Adaptive(AdaptiveSpec {
                max_batch: json_cap(j, "max_batch", d.max_batch)?,
                chunk: f("chunk", d.chunk as f64) as u32,
                group_target: f("group_target", d.group_target as f64) as u32,
                long_prompt: f("long_prompt", d.long_prompt as f64) as u32,
                reload_bias: f("reload_bias", d.reload_bias),
                window_s: f("window_s", d.window_s),
                tbt_slo_s: f("tbt_slo_s", d.tbt_slo_s),
            }));
        }
        if let Some(k) = kind {
            if k != "pipeline" {
                return Err(format!(
                    "unknown policy spec kind '{k}' (valid: pipeline | adaptive)"
                ));
            }
        }
        let admission = match j.get("admission") {
            Some(a) => admission_from_json(a)?,
            None => AdmissionSpec::Fcfs {
                max_batch: MAX_BATCH,
            },
        };
        let shaper = match j.get("shaper") {
            Some(s) => shaper_from_json(s)?,
            None => ShaperSpec::TokenChunks {
                chunk: CHUNK_TOKENS,
            },
        };
        let composer = match j.get("composer") {
            Some(c) => composer_from_json(c)?,
            None => ComposerSpec::Interleave,
        };
        let fairness = match j.get("fairness") {
            Some(f) => fairness_from_json(f)?,
            None => FairnessSpec::None,
        };
        let preemption = match j.get("preemption") {
            Some(p) => preemption_from_json(p)?,
            None => PreemptionSpec::None,
        };
        Ok(PolicySpec::Pipeline {
            name: j.get("name").and_then(Json::as_str).map(str::to_string),
            admission,
            shaper,
            composer,
            fairness,
            preemption,
        })
    }

    /// Serialize to the JSON object form; `parse` round-trips it.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            PolicySpec::Adaptive(a) => {
                m.insert("kind".into(), Json::Str("adaptive".into()));
                m.insert("max_batch".into(), Json::Num(a.max_batch as f64));
                m.insert("chunk".into(), Json::Num(a.chunk as f64));
                m.insert("group_target".into(), Json::Num(a.group_target as f64));
                m.insert("long_prompt".into(), Json::Num(a.long_prompt as f64));
                m.insert("reload_bias".into(), Json::Num(a.reload_bias));
                m.insert("window_s".into(), Json::Num(a.window_s));
                m.insert("tbt_slo_s".into(), Json::Num(a.tbt_slo_s));
            }
            PolicySpec::Pipeline {
                name,
                admission,
                shaper,
                composer,
                fairness,
                preemption,
            } => {
                m.insert("kind".into(), Json::Str("pipeline".into()));
                if let Some(n) = name {
                    m.insert("name".into(), Json::Str(n.clone()));
                }
                m.insert("admission".into(), admission_to_json(admission));
                m.insert("shaper".into(), shaper_to_json(shaper));
                m.insert("composer".into(), composer_to_json(composer));
                // Omitted when None: fairness-free JSON stays byte-stable
                // with pre-tenant builds.
                if let Some(f) = fairness_to_json(fairness) {
                    m.insert("fairness".into(), f);
                }
                // Same omitted-when-None rule for the preemption wrapper.
                if let Some(p) = preemption_to_json(preemption) {
                    m.insert("preemption".into(), p);
                }
            }
        }
        Json::Obj(m)
    }
}

fn admission_label(a: &AdmissionSpec) -> String {
    match *a {
        AdmissionSpec::Fcfs { .. } => "fcfs".to_string(),
        AdmissionSpec::Batch { batch_size } => format!("batch:{batch_size}"),
        AdmissionSpec::Cohort {
            merge,
            merge_target,
            ..
        } => {
            if merge {
                format!("cohort:{merge_target}")
            } else {
                format!("cohort:{merge_target}:nomerge")
            }
        }
        AdmissionSpec::Solo { .. } => "solo".to_string(),
        AdmissionSpec::Srpf { .. } => "srpf".to_string(),
        AdmissionSpec::Srpt { .. } => "srpt".to_string(),
    }
}

fn shaper_label(s: &ShaperSpec) -> String {
    match *s {
        ShaperSpec::TokenChunks { chunk } => format!("chunks:{chunk}"),
        ShaperSpec::FullPrompt => "full".to_string(),
        ShaperSpec::CohortUnit => "cohort".to_string(),
        ShaperSpec::SoloChunk { chunk } => format!("solo:{chunk}"),
    }
}

fn composer_label(c: &ComposerSpec) -> String {
    match *c {
        ComposerSpec::Interleave => "interleave".to_string(),
        ComposerSpec::LayerGroups { target } => format!("groups:{target}"),
    }
}

fn parse_num<T: std::str::FromStr>(v: &str, what: &str) -> Result<T, String> {
    v.trim()
        .parse()
        .map_err(|_| format!("bad {what} '{v}' (want a number)"))
}

/// Token counts that must be at least 1 (a zero chunk/target would admit
/// work and never slice it).
fn parse_tokens(v: &str, what: &str) -> Result<u32, String> {
    let n: u32 = parse_num(v, what)?;
    if n == 0 {
        return Err(format!("bad {what} '{v}' (must be >= 1)"));
    }
    Ok(n)
}

/// Batch caps that must be at least 1 (a zero cap admits nothing and the
/// session would 'drain' with every request unserved).
fn parse_cap(v: &str, what: &str) -> Result<usize, String> {
    let n: usize = parse_num(v, what)?;
    if n == 0 {
        return Err(format!("bad {what} '{v}' (must be >= 1)"));
    }
    Ok(n)
}

/// `admission=cohort:512[:nomerge]`-style stage values.
fn parse_admission(v: &str) -> Result<AdmissionSpec, String> {
    let mut parts = v.split(':');
    let head = parts.next().unwrap_or("");
    let arg1 = parts.next();
    let arg2 = parts.next();
    if parts.next().is_some() {
        return Err(format!(
            "bad admission '{v}' (too many ':' segments; want \
             fcfs[:max] | batch[:size] | cohort[:target][:nomerge] | solo[:max] | \
             srpf[:max] | srpt[:max])"
        ));
    }
    if head != "cohort" && arg2.is_some() {
        return Err(format!("bad admission '{v}' (only cohort takes a second ':' segment)"));
    }
    match head {
        "fcfs" => Ok(AdmissionSpec::Fcfs {
            max_batch: match arg1 {
                Some(a) => parse_cap(a, "fcfs max_batch")?,
                None => MAX_BATCH,
            },
        }),
        "batch" => Ok(AdmissionSpec::Batch {
            batch_size: match arg1 {
                Some(a) => parse_cap(a, "batch size")?,
                None => STATIC_BATCH,
            },
        }),
        "cohort" => {
            let merge = match arg2 {
                None => true,
                Some("nomerge") => false,
                Some(other) => {
                    return Err(format!(
                        "bad cohort flag '{other}' (the only valid third segment is 'nomerge')"
                    ))
                }
            };
            Ok(AdmissionSpec::Cohort {
                max_batch: MAX_BATCH,
                merge,
                merge_target: match arg1 {
                    Some(a) => parse_tokens(a, "cohort merge target")?,
                    None => GROUP_TOKEN_TARGET,
                },
            })
        }
        "solo" => Ok(AdmissionSpec::Solo {
            max_batch: match arg1 {
                Some(a) => parse_cap(a, "solo max_batch")?,
                None => MAX_BATCH,
            },
        }),
        "srpf" => Ok(AdmissionSpec::Srpf {
            max_batch: match arg1 {
                Some(a) => parse_cap(a, "srpf max_batch")?,
                None => MAX_BATCH,
            },
        }),
        "srpt" => Ok(AdmissionSpec::Srpt {
            max_batch: match arg1 {
                Some(a) => parse_cap(a, "srpt max_batch")?,
                None => MAX_BATCH,
            },
        }),
        other => Err(format!(
            "unknown admission '{other}' (valid: fcfs[:max] | batch[:size] | \
             cohort[:target][:nomerge] | solo[:max] | srpf[:max] | srpt[:max])"
        )),
    }
}

/// `preemption=pause[:budget]`-style values (`none` = off). The budget is
/// the max unit boundaries a request may spend paused (min 1).
fn parse_preemption(v: &str) -> Result<PreemptionSpec, String> {
    let (head, arg) = match v.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (v, None),
    };
    match head {
        "none" => {
            if arg.is_some() {
                return Err(format!("bad preemption '{v}' ('none' takes no argument)"));
            }
            Ok(PreemptionSpec::None)
        }
        "pause" => Ok(PreemptionSpec::Pause {
            max_pauses: match arg {
                Some(a) => {
                    let n: u32 = parse_num(a, "pause budget")?;
                    if n == 0 {
                        return Err(format!(
                            "bad pause budget '{a}' (must be >= 1; use preemption=none to \
                             disable)"
                        ));
                    }
                    n
                }
                None => crate::sched::policy::preempt::MAX_PAUSES,
            },
        }),
        other => Err(format!(
            "unknown preemption '{other}' (valid: pause[:budget] | none)"
        )),
    }
}

fn parse_shaper(v: &str) -> Result<ShaperSpec, String> {
    let (head, arg) = match v.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (v, None),
    };
    match head {
        "chunks" => Ok(ShaperSpec::TokenChunks {
            chunk: match arg {
                Some(a) => parse_tokens(a, "chunk size")?,
                None => CHUNK_TOKENS,
            },
        }),
        "full" => Ok(ShaperSpec::FullPrompt),
        "cohort" => Ok(ShaperSpec::CohortUnit),
        "solo" => Ok(ShaperSpec::SoloChunk {
            chunk: match arg {
                Some(a) => parse_tokens(a, "solo chunk size")?,
                None => HYBRID_CHUNK_TOKENS,
            },
        }),
        other => Err(format!(
            "unknown shaper '{other}' (valid: chunks[:n] | full | cohort | solo[:n])"
        )),
    }
}

fn parse_composer(v: &str) -> Result<ComposerSpec, String> {
    let (head, arg) = match v.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (v, None),
    };
    match head {
        "interleave" => Ok(ComposerSpec::Interleave),
        "groups" => Ok(ComposerSpec::LayerGroups {
            target: match arg {
                Some(a) => parse_tokens(a, "group token target")?,
                None => GROUP_TOKEN_TARGET,
            },
        }),
        other => Err(format!(
            "unknown composer '{other}' (valid: interleave | groups[:target])"
        )),
    }
}

fn parse_compact(s: &str) -> Result<PolicySpec, String> {
    // Omitted stages default to the chunked baseline's stage.
    let mut name = None;
    let mut admission = AdmissionSpec::Fcfs {
        max_batch: MAX_BATCH,
    };
    let mut shaper = ShaperSpec::TokenChunks {
        chunk: CHUNK_TOKENS,
    };
    let mut composer = ComposerSpec::Interleave;
    let mut fairness_on: Option<bool> = None;
    let mut weights: Vec<(u32, u32)> = Vec::new();
    let mut preemption = PreemptionSpec::None;
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((k, v)) = part.split_once('=') else {
            return Err(format!(
                "bad pipeline element '{part}' (want key=value with key in \
                 admission | shaper | composer | fairness | weights | preemption | name)"
            ));
        };
        match k.trim().to_ascii_lowercase().as_str() {
            "admission" => admission = parse_admission(&v.trim().to_ascii_lowercase())?,
            "shaper" => shaper = parse_shaper(&v.trim().to_ascii_lowercase())?,
            "composer" => composer = parse_composer(&v.trim().to_ascii_lowercase())?,
            "preemption" => preemption = parse_preemption(&v.trim().to_ascii_lowercase())?,
            "fairness" => {
                fairness_on = Some(match v.trim().to_ascii_lowercase().as_str() {
                    "vtfq" => true,
                    "none" => false,
                    other => {
                        return Err(format!("unknown fairness '{other}' (valid: vtfq | none)"))
                    }
                })
            }
            "weights" => weights = parse_weights(v.trim())?,
            // The display name keeps the user's case (JSON form parity).
            "name" => name = Some(v.trim().to_string()),
            other => {
                return Err(format!(
                    "unknown pipeline key '{other}' (valid: admission | shaper | composer | \
                     fairness | weights | preemption | name)"
                ))
            }
        }
    }
    let fairness = match fairness_on {
        Some(true) => FairnessSpec::Vtfq { weights },
        Some(false) => {
            if !weights.is_empty() {
                return Err("weights=.. requires fairness=vtfq".to_string());
            }
            FairnessSpec::None
        }
        // Explicit weights imply the only fairness policy that uses them.
        None if !weights.is_empty() => FairnessSpec::Vtfq { weights },
        None => FairnessSpec::None,
    };
    Ok(PolicySpec::Pipeline {
        name,
        admission,
        shaper,
        composer,
        fairness,
        preemption,
    })
}

/// `weights=1:4+2:1`-style per-tenant weight overrides: `tenant:weight`
/// pairs joined with `+` (`,` separates pipeline keys).
fn parse_weights(v: &str) -> Result<Vec<(u32, u32)>, String> {
    let mut out = Vec::new();
    for pair in v.split('+') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let Some((id, w)) = pair.split_once(':') else {
            return Err(format!(
                "bad weight '{pair}' (want tenant:weight pairs joined with '+')"
            ));
        };
        let id: u32 = parse_num(id, "weight tenant id")?;
        if id == 0 {
            return Err("tenant id 0 is reserved for untenanted requests".to_string());
        }
        let w: u32 = parse_num(w, "tenant weight")?;
        if w == 0 {
            return Err(format!("bad weight '{pair}' (weight must be >= 1)"));
        }
        out.push((id, w));
    }
    Ok(out)
}

fn parse_adaptive_knobs(s: &str) -> Result<AdaptiveSpec, String> {
    let mut a = AdaptiveSpec::default();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((k, v)) = part.split_once('=') else {
            return Err(format!("bad adaptive knob '{part}' (want key=value)"));
        };
        let v = v.trim();
        match k.trim() {
            "long" | "long_prompt" => a.long_prompt = parse_num(v, "long_prompt")?,
            "window" | "window_s" => a.window_s = parse_num(v, "window_s")?,
            "tbt" | "tbt_slo" => a.tbt_slo_s = parse_num(v, "tbt_slo_s")?,
            "chunk" => a.chunk = parse_num(v, "chunk")?,
            "target" | "group_target" => a.group_target = parse_num(v, "group_target")?,
            "bias" | "reload_bias" => a.reload_bias = parse_num(v, "reload_bias")?,
            "max-batch" | "max_batch" => a.max_batch = parse_cap(v, "max_batch")?,
            other => {
                return Err(format!(
                    "unknown adaptive knob '{other}' (valid: long | window | tbt | chunk | \
                     target | bias | max-batch)"
                ))
            }
        }
    }
    Ok(a)
}

fn req_kind<'j>(j: &'j Json, what: &str) -> Result<&'j str, String> {
    j.get("kind")
        .and_then(Json::as_str)
        .ok_or_else(|| format!("{what} spec needs a string 'kind' field"))
}

/// Optional token-count field that must be >= 1 when present.
fn json_tokens(j: &Json, key: &str, default: u32) -> Result<u32, String> {
    match j.get(key).and_then(Json::as_f64) {
        None => Ok(default),
        Some(x) if x >= 1.0 => Ok(x as u32),
        Some(x) => Err(format!("bad {key} {x} (must be >= 1)")),
    }
}

/// Optional batch-cap field that must be >= 1 when present.
fn json_cap(j: &Json, key: &str, default: usize) -> Result<usize, String> {
    match j.get(key).and_then(Json::as_f64) {
        None => Ok(default),
        Some(x) if x >= 1.0 => Ok(x as usize),
        Some(x) => Err(format!("bad {key} {x} (must be >= 1)")),
    }
}

fn admission_from_json(j: &Json) -> Result<AdmissionSpec, String> {
    let max_batch = json_cap(j, "max_batch", MAX_BATCH)?;
    match req_kind(j, "admission")? {
        "fcfs" => Ok(AdmissionSpec::Fcfs { max_batch }),
        "batch" => Ok(AdmissionSpec::Batch {
            batch_size: json_cap(j, "batch_size", STATIC_BATCH)?,
        }),
        "cohort" => Ok(AdmissionSpec::Cohort {
            max_batch,
            merge: j.get("merge").and_then(Json::as_bool).unwrap_or(true),
            merge_target: json_tokens(j, "target", GROUP_TOKEN_TARGET)?,
        }),
        "solo" => Ok(AdmissionSpec::Solo { max_batch }),
        "srpf" => Ok(AdmissionSpec::Srpf { max_batch }),
        "srpt" => Ok(AdmissionSpec::Srpt { max_batch }),
        other => Err(format!(
            "unknown admission kind '{other}' (valid: fcfs | batch | cohort | solo | srpf | srpt)"
        )),
    }
}

fn preemption_from_json(j: &Json) -> Result<PreemptionSpec, String> {
    match req_kind(j, "preemption")? {
        "none" => Ok(PreemptionSpec::None),
        "pause" => Ok(PreemptionSpec::Pause {
            max_pauses: json_cap(j, "max_pauses", crate::sched::policy::preempt::MAX_PAUSES as usize)?
                as u32,
        }),
        other => Err(format!(
            "unknown preemption kind '{other}' (valid: pause | none)"
        )),
    }
}

/// `None` for [`PreemptionSpec::None`]: like fairness, the field is
/// omitted so preemption-free specs serialize byte-identically to
/// pre-preemption builds.
fn preemption_to_json(p: &PreemptionSpec) -> Option<Json> {
    match *p {
        PreemptionSpec::None => None,
        PreemptionSpec::Pause { max_pauses } => {
            let mut m = BTreeMap::new();
            m.insert("kind".into(), Json::Str("pause".into()));
            m.insert("max_pauses".into(), Json::Num(max_pauses as f64));
            Some(Json::Obj(m))
        }
    }
}

fn shaper_from_json(j: &Json) -> Result<ShaperSpec, String> {
    match req_kind(j, "shaper")? {
        "chunks" => Ok(ShaperSpec::TokenChunks {
            chunk: json_tokens(j, "chunk", CHUNK_TOKENS)?,
        }),
        "full" => Ok(ShaperSpec::FullPrompt),
        "cohort" => Ok(ShaperSpec::CohortUnit),
        "solo" => Ok(ShaperSpec::SoloChunk {
            chunk: json_tokens(j, "chunk", HYBRID_CHUNK_TOKENS)?,
        }),
        other => Err(format!(
            "unknown shaper kind '{other}' (valid: chunks | full | cohort | solo)"
        )),
    }
}

fn composer_from_json(j: &Json) -> Result<ComposerSpec, String> {
    match req_kind(j, "composer")? {
        "interleave" => Ok(ComposerSpec::Interleave),
        "groups" => Ok(ComposerSpec::LayerGroups {
            target: json_tokens(j, "target", GROUP_TOKEN_TARGET)?,
        }),
        other => Err(format!(
            "unknown composer kind '{other}' (valid: interleave | groups)"
        )),
    }
}

fn fairness_from_json(j: &Json) -> Result<FairnessSpec, String> {
    match req_kind(j, "fairness")? {
        "none" => Ok(FairnessSpec::None),
        "vtfq" => {
            let mut weights = Vec::new();
            if let Some(arr) = j.get("weights").and_then(Json::as_arr) {
                for pair in arr {
                    let p = pair.as_arr().unwrap_or(&[]);
                    let (Some(id), Some(w)) = (
                        p.first().and_then(Json::as_f64),
                        p.get(1).and_then(Json::as_f64),
                    ) else {
                        return Err(
                            "bad fairness weights (want [[tenant, weight], ..])".to_string()
                        );
                    };
                    if id < 1.0 || w < 1.0 {
                        return Err(format!(
                            "bad fairness weight [{id}, {w}] (tenant and weight must be >= 1)"
                        ));
                    }
                    weights.push((id as u32, w as u32));
                }
            }
            Ok(FairnessSpec::Vtfq { weights })
        }
        other => Err(format!(
            "unknown fairness kind '{other}' (valid: vtfq | none)"
        )),
    }
}

/// `None` for [`FairnessSpec::None`]: the field is omitted so fairness-free
/// specs serialize byte-identically to pre-tenant builds.
fn fairness_to_json(f: &FairnessSpec) -> Option<Json> {
    match f {
        FairnessSpec::None => None,
        FairnessSpec::Vtfq { weights } => {
            let mut m = BTreeMap::new();
            m.insert("kind".into(), Json::Str("vtfq".into()));
            if !weights.is_empty() {
                m.insert(
                    "weights".into(),
                    Json::Arr(
                        weights
                            .iter()
                            .map(|&(id, w)| {
                                Json::Arr(vec![Json::Num(id as f64), Json::Num(w as f64)])
                            })
                            .collect(),
                    ),
                );
            }
            Some(Json::Obj(m))
        }
    }
}

fn admission_to_json(a: &AdmissionSpec) -> Json {
    let mut m = BTreeMap::new();
    match *a {
        AdmissionSpec::Fcfs { max_batch } => {
            m.insert("kind".into(), Json::Str("fcfs".into()));
            m.insert("max_batch".into(), Json::Num(max_batch as f64));
        }
        AdmissionSpec::Batch { batch_size } => {
            m.insert("kind".into(), Json::Str("batch".into()));
            m.insert("batch_size".into(), Json::Num(batch_size as f64));
        }
        AdmissionSpec::Cohort {
            max_batch,
            merge,
            merge_target,
        } => {
            m.insert("kind".into(), Json::Str("cohort".into()));
            m.insert("max_batch".into(), Json::Num(max_batch as f64));
            m.insert("merge".into(), Json::Bool(merge));
            m.insert("target".into(), Json::Num(merge_target as f64));
        }
        AdmissionSpec::Solo { max_batch } => {
            m.insert("kind".into(), Json::Str("solo".into()));
            m.insert("max_batch".into(), Json::Num(max_batch as f64));
        }
        AdmissionSpec::Srpf { max_batch } => {
            m.insert("kind".into(), Json::Str("srpf".into()));
            m.insert("max_batch".into(), Json::Num(max_batch as f64));
        }
        AdmissionSpec::Srpt { max_batch } => {
            m.insert("kind".into(), Json::Str("srpt".into()));
            m.insert("max_batch".into(), Json::Num(max_batch as f64));
        }
    }
    Json::Obj(m)
}

fn shaper_to_json(s: &ShaperSpec) -> Json {
    let mut m = BTreeMap::new();
    match *s {
        ShaperSpec::TokenChunks { chunk } => {
            m.insert("kind".into(), Json::Str("chunks".into()));
            m.insert("chunk".into(), Json::Num(chunk as f64));
        }
        ShaperSpec::FullPrompt => {
            m.insert("kind".into(), Json::Str("full".into()));
        }
        ShaperSpec::CohortUnit => {
            m.insert("kind".into(), Json::Str("cohort".into()));
        }
        ShaperSpec::SoloChunk { chunk } => {
            m.insert("kind".into(), Json::Str("solo".into()));
            m.insert("chunk".into(), Json::Num(chunk as f64));
        }
    }
    Json::Obj(m)
}

fn composer_to_json(c: &ComposerSpec) -> Json {
    let mut m = BTreeMap::new();
    match *c {
        ComposerSpec::Interleave => {
            m.insert("kind".into(), Json::Str("interleave".into()));
        }
        ComposerSpec::LayerGroups { target } => {
            m.insert("kind".into(), Json::Str("groups".into()));
            m.insert("target".into(), Json::Num(target as f64));
        }
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_names_roundtrip_through_parse() {
        for p in Policy::ALL {
            let spec = PolicySpec::parse(p.name()).unwrap();
            assert_eq!(spec, PolicySpec::preset(p));
            assert_eq!(spec.name(), p.name());
            assert_eq!(spec.matches_preset(), Some(p));
            assert_eq!(spec.nearest_policy(), p);
        }
        // Case-insensitive, plus the legacy aliases.
        assert_eq!(
            PolicySpec::parse("LAYERED").unwrap(),
            PolicySpec::preset(Policy::Layered)
        );
        assert_eq!(
            PolicySpec::parse("Sarathi").unwrap(),
            PolicySpec::preset(Policy::Chunked)
        );
    }

    #[test]
    fn preset_constants_single_source_scheduler_config() {
        // The satellite fix: SchedulerConfig::preset reads THESE constants,
        // so a preset and its spec equivalent cannot drift.
        let cfg = SchedulerConfig::preset(Policy::Layered);
        assert_eq!(cfg.chunk_size, CHUNK_TOKENS);
        assert_eq!(cfg.group_token_target, GROUP_TOKEN_TARGET);
        assert_eq!(cfg.hybrid_chunk_size, HYBRID_CHUNK_TOKENS);
        assert_eq!(cfg.max_batch, MAX_BATCH);
        assert_eq!(cfg.static_batch, STATIC_BATCH);
        for p in Policy::ALL {
            let mirrored = PolicySpec::preset(p).scheduler_config();
            let preset = SchedulerConfig::preset(p);
            assert_eq!(mirrored.chunk_size, preset.chunk_size, "{}", p.name());
            assert_eq!(
                mirrored.group_token_target, preset.group_token_target,
                "{}",
                p.name()
            );
            assert_eq!(mirrored.max_batch, preset.max_batch, "{}", p.name());
            assert_eq!(mirrored.static_batch, preset.static_batch, "{}", p.name());
            assert!(mirrored.spec.is_some());
        }
    }

    #[test]
    fn compact_pipeline_parse() {
        let spec =
            PolicySpec::parse("admission=cohort:256,shaper=chunks:256,composer=groups:128")
                .unwrap();
        let PolicySpec::Pipeline {
            admission,
            shaper,
            composer,
            name,
            fairness,
            preemption,
        } = spec
        else {
            panic!("expected pipeline");
        };
        assert_eq!(fairness, FairnessSpec::None);
        assert_eq!(preemption, PreemptionSpec::None);
        assert_eq!(
            admission,
            AdmissionSpec::Cohort {
                max_batch: MAX_BATCH,
                merge: true,
                merge_target: 256
            }
        );
        assert_eq!(shaper, ShaperSpec::TokenChunks { chunk: 256 });
        assert_eq!(composer, ComposerSpec::LayerGroups { target: 128 });
        assert_eq!(name, None);
        // Omitted stages default to the chunked baseline.
        let spec = PolicySpec::parse("composer=groups").unwrap();
        assert_eq!(spec.nearest_policy(), Policy::Layered);
        // Named specs surface the name, preserving the user's case even
        // though keys and stage values are case-insensitive.
        let spec = PolicySpec::parse("NAME=MyMix,SHAPER=Full").unwrap();
        assert_eq!(spec.name(), "MyMix");
    }

    #[test]
    fn adaptive_parse_and_knobs() {
        assert_eq!(
            PolicySpec::parse("adaptive").unwrap(),
            PolicySpec::Adaptive(AdaptiveSpec::default())
        );
        let PolicySpec::Adaptive(a) =
            PolicySpec::parse("adaptive:long=4096,window=5,tbt=0.05,chunk=256,target=128")
                .unwrap()
        else {
            panic!("expected adaptive");
        };
        assert_eq!(a.long_prompt, 4096);
        assert_eq!(a.window_s, 5.0);
        assert_eq!(a.tbt_slo_s, 0.05);
        assert_eq!(a.chunk, 256);
        assert_eq!(a.group_target, 128);
        assert!(PolicySpec::parse("adaptive:bogus=1").is_err());
    }

    #[test]
    fn errors_list_valid_alternatives() {
        let e = PolicySpec::parse("nosuch").unwrap_err();
        assert!(e.contains("static"), "{e}");
        assert!(e.contains("adaptive"), "{e}");
        let e = PolicySpec::parse("admission=nosuch").unwrap_err();
        assert!(e.contains("fcfs"), "{e}");
        let e = PolicySpec::parse("shaper=nosuch").unwrap_err();
        assert!(e.contains("chunks"), "{e}");
        let e = PolicySpec::parse("composer=nosuch").unwrap_err();
        assert!(e.contains("interleave"), "{e}");
        // Zero token budgets would admit work and never slice it.
        assert!(PolicySpec::parse("shaper=chunks:0").is_err());
        assert!(PolicySpec::parse("composer=groups:0").is_err());
        assert!(PolicySpec::parse(r#"{"shaper":{"kind":"chunks","chunk":0}}"#).is_err());
        // Zero batch caps would admit nothing and 'drain' unserved work.
        assert!(PolicySpec::parse("admission=fcfs:0").is_err());
        assert!(PolicySpec::parse("admission=batch:0").is_err());
        assert!(PolicySpec::parse("adaptive:max-batch=0").is_err());
        assert!(
            PolicySpec::parse(r#"{"admission":{"kind":"solo","max_batch":0}}"#).is_err()
        );
        // A misspelled cohort flag must not silently flip the merge knob.
        let e = PolicySpec::parse("admission=cohort:512:nomerg").unwrap_err();
        assert!(e.contains("nomerge"), "{e}");
        assert!(PolicySpec::parse("admission=cohort:512:nomerge:x").is_err());
    }

    #[test]
    fn json_roundtrips_every_form() {
        let specs = vec![
            PolicySpec::preset(Policy::Layered),
            PolicySpec::preset(Policy::Static),
            PolicySpec::Adaptive(AdaptiveSpec {
                long_prompt: 999,
                ..AdaptiveSpec::default()
            }),
            PolicySpec::Pipeline {
                name: Some("weird".into()),
                admission: AdmissionSpec::Batch { batch_size: 3 },
                shaper: ShaperSpec::SoloChunk { chunk: 2048 },
                composer: ComposerSpec::LayerGroups { target: 256 },
                fairness: FairnessSpec::None,
                preemption: PreemptionSpec::None,
            },
            PolicySpec::Pipeline {
                name: None,
                admission: AdmissionSpec::Fcfs {
                    max_batch: MAX_BATCH,
                },
                shaper: ShaperSpec::TokenChunks {
                    chunk: CHUNK_TOKENS,
                },
                composer: ComposerSpec::Interleave,
                fairness: FairnessSpec::Vtfq {
                    weights: vec![(1, 4), (2, 1)],
                },
                preemption: PreemptionSpec::None,
            },
            PolicySpec::Pipeline {
                name: None,
                admission: AdmissionSpec::Srpt { max_batch: 64 },
                shaper: ShaperSpec::CohortUnit,
                composer: ComposerSpec::LayerGroups { target: 512 },
                fairness: FairnessSpec::Vtfq { weights: vec![] },
                preemption: PreemptionSpec::Pause { max_pauses: 2 },
            },
        ];
        for spec in specs {
            let text = spec.to_json().to_string();
            let back = PolicySpec::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, spec, "{text}");
        }
    }

    #[test]
    fn fairness_parses_composes_and_roundtrips() {
        // Compact form: fairness=vtfq with '+'-joined weight pairs.
        let spec = PolicySpec::parse("shaper=chunks:256,fairness=vtfq,weights=1:4+2:1").unwrap();
        let PolicySpec::Pipeline { ref fairness, .. } = spec else {
            panic!("expected pipeline");
        };
        assert_eq!(
            *fairness,
            FairnessSpec::Vtfq {
                weights: vec![(1, 4), (2, 1)]
            }
        );
        // A fairness wrapper is never a preset, and the derived label
        // carries the +vtfq tag.
        assert_eq!(spec.matches_preset(), None);
        assert!(spec.name().ends_with("+vtfq"), "{}", spec.name());
        // JSON round-trip keeps the weights.
        let back = PolicySpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        // Weights imply vtfq; fairness=none with weights is contradictory.
        let implied = PolicySpec::parse("weights=3:2").unwrap();
        assert_eq!(
            implied.name(),
            "pipeline(fcfs+chunks:512+interleave)+vtfq"
        );
        assert!(PolicySpec::parse("fairness=none,weights=1:2").is_err());
        assert!(PolicySpec::parse("fairness=bogus").is_err());
        // Tenant 0 and zero weights are invalid.
        assert!(PolicySpec::parse("weights=0:2").is_err());
        assert!(PolicySpec::parse("weights=1:0").is_err());
        // The chunked preset stays a preset (fairness None by default) —
        // feature-off parse output is unchanged.
        assert_eq!(
            PolicySpec::parse("chunked").unwrap().matches_preset(),
            Some(Policy::Chunked)
        );
        // vtfq composes with the layer-axis composer too.
        let layered = PolicySpec::parse("admission=cohort,shaper=cohort,composer=groups,fairness=vtfq")
            .unwrap();
        assert_eq!(layered.nearest_policy(), Policy::Layered);
        layered.build(32); // compiles into a scheduler without panicking
    }

    #[test]
    fn preemption_and_sized_admission_parse_compose_and_roundtrip() {
        // Compact form: srpf admission + pause preemption with a budget.
        let spec = PolicySpec::parse("admission=srpf,preemption=pause:2").unwrap();
        let PolicySpec::Pipeline {
            ref admission,
            ref preemption,
            ..
        } = spec
        else {
            panic!("expected pipeline");
        };
        assert_eq!(*admission, AdmissionSpec::Srpf { max_batch: MAX_BATCH });
        assert_eq!(*preemption, PreemptionSpec::Pause { max_pauses: 2 });
        // A preempting wrapper is never a preset; the label carries the
        // +preempt tag and the srpf admission head.
        assert_eq!(spec.matches_preset(), None);
        assert!(spec.name().contains("srpf"), "{}", spec.name());
        assert!(spec.name().ends_with("+preempt"), "{}", spec.name());
        // JSON round-trip keeps admission kind and pause budget.
        let back = PolicySpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        // Bare pause takes the default budget; srpt parses with a cap.
        let bare = PolicySpec::parse("preemption=pause").unwrap();
        let PolicySpec::Pipeline { ref preemption, .. } = bare else {
            panic!("expected pipeline");
        };
        assert_eq!(
            *preemption,
            PreemptionSpec::Pause {
                max_pauses: crate::sched::policy::preempt::MAX_PAUSES
            }
        );
        let srpt = PolicySpec::parse("admission=srpt:32").unwrap();
        let PolicySpec::Pipeline { ref admission, .. } = srpt else {
            panic!("expected pipeline");
        };
        assert_eq!(*admission, AdmissionSpec::Srpt { max_batch: 32 });
        // Invalid forms: zero budget, argument on none, unknown kind.
        assert!(PolicySpec::parse("preemption=pause:0").is_err());
        assert!(PolicySpec::parse("preemption=none:3").is_err());
        assert!(PolicySpec::parse("preemption=bogus").is_err());
        // Presets stay presets — feature-off parse output is unchanged.
        assert_eq!(
            PolicySpec::parse("layered").unwrap().matches_preset(),
            Some(Policy::Layered)
        );
        // Preemption composes with fairness and the layer-axis composer.
        let full = PolicySpec::parse(
            "admission=srpt,shaper=cohort,composer=groups,fairness=vtfq,preemption=pause",
        )
        .unwrap();
        assert_eq!(full.nearest_policy(), Policy::Layered);
        full.build(32); // compiles into a scheduler without panicking
    }

    #[test]
    fn nearest_policy_classifies_by_composer_axis() {
        let layer = PolicySpec::parse("shaper=full,composer=groups:128").unwrap();
        assert_eq!(layer.nearest_policy(), Policy::Layered);
        let token = PolicySpec::parse("admission=batch:4,shaper=chunks:128").unwrap();
        assert_eq!(token.nearest_policy(), Policy::Chunked);
        assert_eq!(
            PolicySpec::Adaptive(AdaptiveSpec::default()).nearest_policy(),
            Policy::Layered
        );
    }
}
