//! Concrete pipeline stages. Each of the five legacy policies is one
//! canonical (admission, shaper, composer) triple — see
//! [`PolicySpec::preset`](crate::sched::policy::PolicySpec::preset) — and
//! every stage is reusable in novel compositions. Stage behavior is an
//! EXACT decomposition of the legacy policy code: the preset compositions
//! are bit-identity-locked against direct construction by
//! `tests/policy_spec.rs`.

use crate::sched::policy::{AdmissionPolicy, BatchComposer, PrefillShaper, PrefillUnit};
use crate::sched::{
    groups_for_len, partition_layers, EngineState, GroupPlan, IterationPlan, PrefillWork,
};

// ---------------------------------------------------------------------------
// Admission policies
// ---------------------------------------------------------------------------

/// Greedy FCFS admission: admit the head of the waiting queue while the
/// batch cap and KV capacity allow (chunked / Orca). KV exhaustion
/// head-of-line blocks — no bypass — matching Sarathi's FCFS rule.
#[derive(Debug)]
pub struct GreedyAdmission {
    max_batch: usize,
}

impl GreedyAdmission {
    pub fn new(max_batch: usize) -> Self {
        GreedyAdmission { max_batch }
    }
}

impl AdmissionPolicy for GreedyAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(&head) = state.waiting.first() {
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.max_batch) {
                break;
            }
            if !state.admit(head) {
                break;
            }
            out.push(head);
        }
        out
    }
}

/// Size-aware admission (`admission=srpf` / `admission=srpt`): the waiting
/// queue is stably reordered by `(priority desc, size asc, FCFS position)`
/// before greedy head-of-queue admission — shortest-remaining-prefill-first
/// when `include_output` is false, SRPT (remaining prefill + declared
/// output) when true. Higher priority classes always order first, so an
/// interactive arrival jumps every baseline-class prompt regardless of
/// size. Like [`GreedyAdmission`], the first refusal stops the round (no
/// KV-exhaustion bypass).
#[derive(Debug)]
pub struct SizedAdmission {
    max_batch: usize,
    include_output: bool,
}

impl SizedAdmission {
    /// Shortest-remaining-prefill-first.
    pub fn srpf(max_batch: usize) -> Self {
        SizedAdmission {
            max_batch,
            include_output: false,
        }
    }

    /// Shortest-remaining-processing-time: remaining prefill + declared
    /// output length.
    pub fn srpt(max_batch: usize) -> Self {
        SizedAdmission {
            max_batch,
            include_output: true,
        }
    }

    fn size_key(&self, state: &EngineState, id: u64) -> u64 {
        let r = &state.reqs[&id];
        let mut k = r.remaining_prefill() as u64;
        if self.include_output {
            k += r.req.output_len as u64;
        }
        k
    }
}

impl AdmissionPolicy for SizedAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        if state.waiting.len() > 1 {
            let mut keyed: Vec<(std::cmp::Reverse<u8>, u64, usize, u64)> = state
                .waiting
                .iter()
                .enumerate()
                .map(|(pos, &id)| {
                    (
                        std::cmp::Reverse(state.reqs[&id].req.priority),
                        self.size_key(state, id),
                        pos,
                        id,
                    )
                })
                .collect();
            keyed.sort();
            for (slot, k) in keyed.into_iter().enumerate() {
                state.waiting[slot] = k.3;
            }
        }
        let mut out = Vec::new();
        while let Some(&head) = state.waiting.first() {
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.max_batch) {
                break;
            }
            if !state.admit(head) {
                break;
            }
            out.push(head);
        }
        out
    }
}

/// Fixed-batch run-to-completion admission (static batching): a new batch
/// of up to `batch_size` requests forms only once EVERY member of the
/// previous batch has finished.
#[derive(Debug)]
pub struct BatchAdmission {
    batch_size: usize,
    /// The in-flight batch; no admissions until it fully drains.
    batch: Vec<u64>,
}

impl BatchAdmission {
    pub fn new(batch_size: usize) -> Self {
        BatchAdmission {
            batch_size,
            batch: Vec::new(),
        }
    }

    fn batch_done(&self, state: &EngineState) -> bool {
        self.batch.iter().all(|id| {
            state
                .reqs
                .get(id)
                .map(|r| r.phase == crate::sched::Phase::Finished)
                .unwrap_or(true)
        })
    }
}

impl AdmissionPolicy for BatchAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        if !self.batch_done(state) {
            return Vec::new();
        }
        self.batch.clear();
        while self.batch.len() < self.batch_size {
            let Some(&head) = state.waiting.first() else {
                break;
            };
            if !state.admit(head) {
                break;
            }
            self.batch.push(head);
        }
        self.batch.clone()
    }
}

/// Cohort admission (layered prefill, paper §4.4): admit the FCFS head,
/// then merge further waiting requests while the combined DECLARED prompt
/// length stays within `merge_target` (so merged admissions still cost
/// about one chunk-sized unit per iteration) and capacity allows. The
/// merge budget is judged on declared lengths — pre prefix-cache credit —
/// so the cohort shape is deterministic and cache-temperature-independent.
#[derive(Debug)]
pub struct CohortAdmission {
    max_batch: usize,
    merge: bool,
    merge_target: u32,
}

impl CohortAdmission {
    pub fn new(max_batch: usize, merge: bool, merge_target: u32) -> Self {
        CohortAdmission {
            max_batch,
            merge,
            merge_target,
        }
    }
}

impl AdmissionPolicy for CohortAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        let mut cohort: Vec<u64> = Vec::new();
        let mut merged_declared: u32 = 0;
        loop {
            let Some(&head) = state.waiting.first() else {
                break;
            };
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.max_batch) {
                break;
            }
            let head_len = state.reqs[&head].req.input_len;
            if !cohort.is_empty() {
                if !self.merge {
                    break;
                }
                if merged_declared.saturating_add(head_len) > self.merge_target {
                    break;
                }
            }
            if !state.admit(head) {
                break;
            }
            merged_declared = merged_declared.saturating_add(head_len);
            cohort.push(head);
        }
        cohort
    }
}

/// One-at-a-time admission (hybrid, paper §4.3): a new request is admitted
/// only when no already-admitted request has prefill work remaining, so
/// exactly one prompt is mid-flight on the chunk+layer pipeline at a time.
#[derive(Debug)]
pub struct SoloAdmission {
    max_batch: usize,
}

impl SoloAdmission {
    pub fn new(max_batch: usize) -> Self {
        SoloAdmission { max_batch }
    }
}

impl AdmissionPolicy for SoloAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        let busy = state
            .prefilling
            .iter()
            .any(|id| state.reqs[id].remaining_prefill() > 0);
        if busy {
            return Vec::new();
        }
        let Some(&head) = state.waiting.first() else {
            return Vec::new();
        };
        let active = state.prefilling.len() + state.decoding.len();
        if active >= state.max_batch.min(self.max_batch) {
            return Vec::new();
        }
        if state.admit(head) {
            vec![head]
        } else {
            Vec::new()
        }
    }
}

// ---------------------------------------------------------------------------
// Prefill shapers
// ---------------------------------------------------------------------------

/// Token-axis budget chunking (Sarathi): fill a `chunk`-token budget FCFS
/// across ALL admitted prefills, coalescing short prompts into one unit.
/// Requests with zero remaining prefill (empty / fully-cached prompts)
/// always get a zero-token completing slice — costs nothing, consumes no
/// budget, and never strands the request in Prefilling.
#[derive(Debug)]
pub struct TokenChunkShaper {
    chunk: u32,
}

impl TokenChunkShaper {
    /// `chunk` is clamped to at least 1: a zero budget would admit
    /// requests and then never slice them — the session would drain with
    /// work silently stranded (spec parsing also rejects 0 up front).
    pub fn new(chunk: u32) -> Self {
        TokenChunkShaper {
            chunk: chunk.max(1),
        }
    }
}

impl PrefillShaper for TokenChunkShaper {
    fn shape(&mut self, state: &EngineState, _admitted: &[u64]) -> PrefillUnit {
        let mut budget = self.chunk;
        let mut slices = Vec::new();
        let mut total: u32 = 0;
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            if remaining == 0 {
                slices.push(PrefillWork {
                    req: id,
                    tokens: 0,
                    pos: r.prefill_done,
                    completes: true,
                });
                continue;
            }
            if budget == 0 {
                continue;
            }
            let take = remaining.min(budget);
            slices.push(PrefillWork {
                req: id,
                tokens: take,
                pos: r.prefill_done,
                completes: take == remaining,
            });
            budget -= take;
            total += take;
        }
        PrefillUnit {
            slices,
            tokens: total,
        }
    }
}

/// Whole-prompt shaping (Orca / static): every admitted prefill runs its
/// ENTIRE remaining prompt as one completing slice.
#[derive(Debug, Default)]
pub struct FullPromptShaper;

impl FullPromptShaper {
    pub fn new() -> Self {
        FullPromptShaper
    }
}

impl PrefillShaper for FullPromptShaper {
    fn shape(&mut self, state: &EngineState, _admitted: &[u64]) -> PrefillUnit {
        let mut slices = Vec::new();
        let mut total: u32 = 0;
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            slices.push(PrefillWork {
                req: id,
                tokens: remaining,
                pos: r.prefill_done,
                completes: true,
            });
            total = total.saturating_add(remaining);
        }
        PrefillUnit {
            slices,
            tokens: total,
        }
    }
}

/// Cohort shaping (layered prefill): the admission cohort's full remaining
/// prefill — post prefix-cache credit — becomes one unit, so the layer-axis
/// composer sizes G from the cohort's REMAINING work and warm-prefix
/// cohorts complete in fewer iterations.
#[derive(Debug, Default)]
pub struct CohortShaper;

impl CohortShaper {
    pub fn new() -> Self {
        CohortShaper
    }
}

impl PrefillShaper for CohortShaper {
    fn shape(&mut self, state: &EngineState, admitted: &[u64]) -> PrefillUnit {
        let mut slices = Vec::new();
        let mut total: u32 = 0;
        for &id in admitted {
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            slices.push(PrefillWork {
                req: id,
                tokens: remaining,
                pos: r.prefill_done,
                completes: true,
            });
            total = total.saturating_add(remaining);
        }
        // Straggler sweep: a RESUMED (previously preempted) prefill sits in
        // `state.prefilling` without being in this round's cohort; fold its
        // remaining work into the unit so no composition strands it.
        // Without preemption this matches nothing — a cohort's members
        // always finish their prefill with their own unit.
        for &id in &state.prefilling {
            if admitted.contains(&id) {
                continue;
            }
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            slices.push(PrefillWork {
                req: id,
                tokens: remaining,
                pos: r.prefill_done,
                completes: true,
            });
            total = total.saturating_add(remaining);
        }
        PrefillUnit {
            slices,
            tokens: total,
        }
    }
}

/// One-request large-chunk shaping (hybrid): the first in-flight request
/// with remaining prefill contributes its next `chunk`-token span; the
/// slice completes only when it is the prompt's final chunk. Zero-remaining
/// prefills are swept into the unit as zero-token completing slices so no
/// composition can strand them.
#[derive(Debug)]
pub struct SoloChunkShaper {
    chunk: u32,
}

impl SoloChunkShaper {
    /// `chunk` is clamped to at least 1 (see [`TokenChunkShaper::new`]).
    pub fn new(chunk: u32) -> Self {
        SoloChunkShaper {
            chunk: chunk.max(1),
        }
    }
}

impl PrefillShaper for SoloChunkShaper {
    fn shape(&mut self, state: &EngineState, _admitted: &[u64]) -> PrefillUnit {
        let mut slices = Vec::new();
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            if r.remaining_prefill() == 0 {
                slices.push(PrefillWork {
                    req: id,
                    tokens: 0,
                    pos: r.prefill_done,
                    completes: true,
                });
            }
        }
        let candidate = state
            .prefilling
            .iter()
            .copied()
            .find(|id| state.reqs[id].remaining_prefill() > 0);
        let mut total: u32 = 0;
        if let Some(id) = candidate {
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            let take = remaining.min(self.chunk);
            slices.push(PrefillWork {
                req: id,
                tokens: take,
                pos: r.prefill_done,
                completes: take == remaining,
            });
            total = take;
        }
        PrefillUnit {
            slices,
            tokens: total,
        }
    }
}

// ---------------------------------------------------------------------------
// Batch composers
// ---------------------------------------------------------------------------

/// Token-axis composition: the whole unit runs in ONE iteration as a single
/// full-stack hybrid batch (prefill slices + every ongoing decode), the
/// Sarathi/Orca/static shape.
#[derive(Debug)]
pub struct InterleaveComposer {
    n_layers: u32,
    unit: Option<PrefillUnit>,
}

impl InterleaveComposer {
    pub fn new(n_layers: u32) -> Self {
        InterleaveComposer {
            n_layers,
            unit: None,
        }
    }
}

impl BatchComposer for InterleaveComposer {
    fn needs_unit(&self) -> bool {
        self.unit.is_none()
    }

    fn load(&mut self, unit: PrefillUnit) {
        self.unit = Some(unit);
    }

    fn compose(&mut self, state: &EngineState) -> Option<IterationPlan> {
        let prefill = self.unit.take().map(|u| u.slices).unwrap_or_default();
        let decode = state.decode_set();
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(IterationPlan {
            groups: vec![GroupPlan {
                n_layers: self.n_layers,
                prefill,
                decode,
            }],
        })
    }
}

/// Layer-axis composition (the paper's contribution, §4): the unit's
/// tokens size G = ceil(tokens / target), clamped to the layer count; the
/// stack partitions into G contiguous groups and exactly ONE group
/// prefills the unit per iteration (I1) while every group carries the
/// decode batch (I3). The unit completes in exactly G iterations (I4);
/// slices complete only on the last group. A zero-token unit (empty or
/// fully-cached cohort) clamps to a single full-stack group so the
/// zero-work admission still completes through an iteration.
#[derive(Debug)]
pub struct LayerGroupComposer {
    n_layers: u32,
    target: u32,
    unit: Option<PrefillUnit>,
    group_sizes: Vec<u32>,
    cursor: usize,
}

impl LayerGroupComposer {
    pub fn new(n_layers: u32, target: u32) -> Self {
        LayerGroupComposer {
            n_layers,
            target,
            unit: None,
            group_sizes: Vec::new(),
            cursor: 0,
        }
    }
}

impl BatchComposer for LayerGroupComposer {
    fn needs_unit(&self) -> bool {
        self.unit.is_none()
    }

    fn load(&mut self, unit: PrefillUnit) {
        let g = groups_for_len(unit.tokens, self.target).min(self.n_layers);
        self.group_sizes = partition_layers(self.n_layers, g);
        self.cursor = 0;
        if self.group_sizes.is_empty() {
            // Zero-layer model: there is nothing to schedule the unit on
            // (partition_layers(0, _) is the documented empty partition).
            self.unit = None;
            return;
        }
        self.unit = Some(unit);
    }

    fn compose(&mut self, state: &EngineState) -> Option<IterationPlan> {
        let decode = state.decode_set();
        let Some(unit) = &self.unit else {
            if decode.is_empty() {
                return None;
            }
            // Decode-only iteration: a single full-stack group.
            return Some(IterationPlan {
                groups: vec![GroupPlan {
                    n_layers: self.n_layers,
                    prefill: Vec::new(),
                    decode,
                }],
            });
        };

        let last = self.cursor == self.group_sizes.len() - 1;
        let mut groups = Vec::with_capacity(self.group_sizes.len());
        for (gi, &gsize) in self.group_sizes.iter().enumerate() {
            let prefill = if gi == self.cursor {
                unit.slices
                    .iter()
                    .map(|w| PrefillWork {
                        completes: w.completes && last,
                        ..*w
                    })
                    .collect()
            } else {
                Vec::new()
            };
            groups.push(GroupPlan {
                n_layers: gsize,
                prefill,
                decode: decode.clone(),
            });
        }
        self.cursor += 1;
        if last {
            self.unit = None;
            self.group_sizes.clear();
            self.cursor = 0;
        }
        Some(IterationPlan { groups })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;
    use crate::kvcache::KvCacheManager;
    use crate::sched::Phase;
    use crate::workload::Request;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100_000, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    #[test]
    fn greedy_admission_respects_batch_cap() {
        let mut st = state();
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 100, 5));
        st.arrive(req(3, 100, 5));
        let mut a = GreedyAdmission::new(2);
        assert_eq!(a.admit(&mut st), vec![1, 2]);
        assert_eq!(st.waiting, vec![3]);
    }

    #[test]
    fn batch_admission_waits_for_full_drain() {
        let mut st = state();
        st.arrive(req(1, 100, 4));
        st.arrive(req(2, 100, 4));
        st.arrive(req(3, 100, 4));
        let mut a = BatchAdmission::new(2);
        assert_eq!(a.admit(&mut st), vec![1, 2]);
        // Batch in flight: no admissions.
        assert!(a.admit(&mut st).is_empty());
        assert_eq!(st.waiting, vec![3]);
        // Finish the batch: the next round admits request 3.
        for id in [1u64, 2] {
            st.reqs.get_mut(&id).unwrap().phase = Phase::Finished;
        }
        st.prefilling.clear();
        assert_eq!(a.admit(&mut st), vec![3]);
    }

    #[test]
    fn cohort_admission_merges_up_to_target() {
        let mut st = state();
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 150, 5));
        st.arrive(req(3, 200, 5));
        st.arrive(req(4, 400, 5)); // would exceed the 512 merged target
        let mut a = CohortAdmission::new(256, true, 512);
        assert_eq!(a.admit(&mut st), vec![1, 2, 3]);
        assert_eq!(st.waiting, vec![4]);
        // merge off: one request per cohort.
        let mut st = state();
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 100, 5));
        let mut a = CohortAdmission::new(256, false, 512);
        assert_eq!(a.admit(&mut st), vec![1]);
    }

    #[test]
    fn solo_admission_blocks_while_prefill_in_flight() {
        let mut st = state();
        st.arrive(req(1, 1000, 5));
        st.arrive(req(2, 1000, 5));
        let mut a = SoloAdmission::new(256);
        assert_eq!(a.admit(&mut st), vec![1]);
        // Request 1 still has remaining prefill: nothing new admits.
        assert!(a.admit(&mut st).is_empty());
        st.reqs.get_mut(&1).unwrap().prefill_done = 1000;
        assert_eq!(a.admit(&mut st), vec![2]);
    }

    #[test]
    fn token_chunks_coalesce_and_respect_budget() {
        let mut st = state();
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 600, 5));
        let mut a = GreedyAdmission::new(256);
        let ids = a.admit(&mut st);
        let mut sh = TokenChunkShaper::new(512);
        let u = sh.shape(&st, &ids);
        assert_eq!(u.tokens, 512);
        assert_eq!(u.slices.len(), 2);
        assert!(u.slices[0].completes);
        assert_eq!(u.slices[1].tokens, 412);
        assert!(!u.slices[1].completes);
    }

    #[test]
    fn solo_chunk_sweeps_zero_remaining_prefills() {
        // A composition the legacy hybrid could not reach: multiple
        // admitted requests, one empty prompt among them. The sweep keeps
        // the empty prompt completing instead of stranding.
        let mut st = state();
        st.arrive(req(1, 0, 3));
        st.arrive(req(2, 5000, 5));
        let mut a = GreedyAdmission::new(256);
        let ids = a.admit(&mut st);
        let mut sh = SoloChunkShaper::new(4096);
        let u = sh.shape(&st, &ids);
        assert_eq!(u.slices.len(), 2);
        let zero = u.slices.iter().find(|w| w.req == 1).unwrap();
        assert_eq!(zero.tokens, 0);
        assert!(zero.completes);
        let chunk = u.slices.iter().find(|w| w.req == 2).unwrap();
        assert_eq!(chunk.tokens, 4096);
        assert!(!chunk.completes);
        assert_eq!(u.tokens, 4096);
    }

    #[test]
    fn layer_group_composer_holds_slices_for_g_iterations() {
        let mut st = state();
        st.arrive(req(1, 2048, 5));
        let mut a = GreedyAdmission::new(256);
        let ids = a.admit(&mut st);
        let mut sh = CohortShaper::new();
        let mut c = LayerGroupComposer::new(48, 512);
        assert!(c.needs_unit());
        c.load(sh.shape(&st, &ids));
        for it in 0..4 {
            assert!(!c.needs_unit() || it == 0);
            let p = c.compose(&st).unwrap();
            assert_eq!(p.groups.len(), 4);
            assert_eq!(p.prefill_groups(), 1);
            let w = p.groups[it].prefill[0];
            assert_eq!(w.tokens, 2048);
            assert_eq!(w.completes, it == 3, "completes only on the last group");
        }
        assert!(c.needs_unit(), "unit consumed after G iterations");
    }

    #[test]
    fn composers_emit_decode_only_plans_when_idle() {
        let mut st = state();
        st.arrive(req(7, 10, 50));
        st.admit(7);
        {
            let r = st.reqs.get_mut(&7).unwrap();
            r.prefill_done = 10;
            r.generated = 1;
            r.phase = Phase::Decoding;
        }
        st.prefilling.clear();
        st.decoding.push(7);
        let mut ic = InterleaveComposer::new(48);
        let p = ic.compose(&st).unwrap();
        assert!(p.groups[0].prefill.is_empty());
        assert_eq!(p.groups[0].decode.len(), 1);
        let mut lc = LayerGroupComposer::new(48, 512);
        let p = lc.compose(&st).unwrap();
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.total_layers(), 48);
        // And with neither prefill nor decode, both report no work.
        let empty = state();
        assert!(InterleaveComposer::new(48).compose(&empty).is_none());
        assert!(LayerGroupComposer::new(48, 512).compose(&empty).is_none());
    }

    #[test]
    fn zero_token_unit_clamps_to_single_group() {
        let mut st = state();
        st.arrive(req(1, 0, 3));
        let mut a = GreedyAdmission::new(256);
        let ids = a.admit(&mut st);
        let mut sh = CohortShaper::new();
        let mut c = LayerGroupComposer::new(48, 512);
        c.load(sh.shape(&st, &ids));
        let p = c.compose(&st).unwrap();
        assert_eq!(p.groups.len(), 1);
        let w = p.groups[0].prefill[0];
        assert_eq!(w.tokens, 0);
        assert!(w.completes);
    }
}
