//! Signal-driven adaptive scheduling: the paper's §4.3 hybrid generalized
//! into a RUNTIME policy.
//!
//! The paper shows the scheduling axis is workload-dependent: token-axis
//! chunking wins on short prompts (no reload amplification, no G-iteration
//! cadence), the layer axis wins on long prompts (each layer's experts
//! load once per prompt instead of once per chunk). [`AdaptiveScheduler`]
//! therefore re-evaluates the axis **per admission cohort** from live
//! signals observed on the engine state:
//!
//! * the cohort's remaining prefill and the waiting queue's prompt-length
//!   mix;
//! * a `moe::traffic`-style expert-reload estimate: modeled expert-load
//!   bytes for chunking the cohort vs one layer-axis pass
//!   ([`axis_expert_bytes`], using the paper's coverage model);
//! * windowed TTFT / latest-TBT over the LIVE decode batch — a bounded
//!   (O(max_batch), never O(requests-served)) read off `EngineState`, so
//!   the policy needs no side channel to the `StreamingSlo` sink.
//!
//! The decision rule itself consumes the cohort length, the reload
//! ratio, and the TBT signal; the queue mix and windowed TTFT ride in
//! the [`SignalSnapshot`] for observability and future rules.
//!
//! Both arms reuse the pipeline stages, so I1–I4 hold by construction:
//! the token arm is Sarathi-style budget chunking through
//! [`InterleaveComposer`]; the layer arm shapes ALL in-flight remaining
//! prefill into one unit over G = ceil(L/target) groups
//! ([`LayerGroupComposer`]). Axis switches happen only between units, so
//! no in-flight layer-axis obligation is ever abandoned and no admitted
//! request can strand (the layer arm's whole-remaining shaping also
//! adopts any mid-chunk leftovers from the token arm).

use crate::config::ModelDesc;
use crate::moe::coverage::CoverageModel;
use crate::sched::policy::spec::AdaptiveSpec;
use crate::sched::policy::stages::{
    FullPromptShaper, GreedyAdmission, InterleaveComposer, LayerGroupComposer, TokenChunkShaper,
};
use crate::sched::policy::{AdmissionPolicy, BatchComposer, PrefillShaper};
use crate::sched::{EngineState, IterationPlan, Phase, Scheduler};

/// The scheduling axis an adaptive cohort runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    /// Token-axis: budget chunks through one full-stack group per
    /// iteration.
    Token,
    /// Layer-axis: the full remaining prefill over G layer groups, one
    /// group per iteration.
    Layer,
}

/// Live signals sampled at an admission-cohort boundary.
#[derive(Clone, Copy, Debug, Default)]
pub struct SignalSnapshot {
    /// Remaining prefill tokens of the cohort just admitted (post
    /// prefix-cache credit).
    pub cohort_remaining: u32,
    /// Mean declared prompt length over the still-waiting queue (the
    /// upcoming length mix; 0.0 when empty).
    pub waiting_mean_len: f64,
    /// Modeled expert-load bytes to prefill the cohort on the token axis
    /// (one full-stack pass per chunk).
    pub token_axis_expert_bytes: f64,
    /// Modeled expert-load bytes on the layer axis (each layer's experts
    /// load once over the whole cohort).
    pub layer_axis_expert_bytes: f64,
    /// Max TTFT among LIVE (decoding) requests whose first token landed
    /// inside the window. Exposed for observability and future rules; the
    /// current decision rule does not consume it.
    pub window_ttft_max_s: f64,
    /// Max LATEST inter-token gap across the live decode batch. A
    /// decoding request decodes every iteration (I3), so its latest gap
    /// is at most one iteration old — a genuinely current TBT reading.
    pub window_tbt_max_s: f64,
}

impl SignalSnapshot {
    /// Sample the signals from engine state at a cohort boundary.
    /// `admitted` is the cohort the admission stage just produced.
    pub fn observe(
        state: &EngineState,
        admitted: &[u64],
        window_s: f64,
        chunk: u32,
    ) -> SignalSnapshot {
        let cohort_remaining = admitted
            .iter()
            .fold(0u32, |a, id| a.saturating_add(state.reqs[id].remaining_prefill()));
        let waiting_mean_len = if state.waiting.is_empty() {
            0.0
        } else {
            let total: u64 = state
                .waiting
                .iter()
                .map(|id| state.reqs[id].req.input_len as u64)
                .sum();
            total as f64 / state.waiting.len() as f64
        };
        let (token_axis_expert_bytes, layer_axis_expert_bytes) =
            axis_expert_bytes(&state.model, cohort_remaining, chunk);
        // Latency signals from the LIVE decode set only — bounded by the
        // batch cap, never a rescan of every record ever served, so an
        // hours-long open-loop session pays O(max_batch) per cohort
        // boundary. Each decoding request contributes its latest gap
        // (at most one iteration old — I3) and, when its first token
        // landed inside (now - window, now], its TTFT.
        let cut = state.now_s - window_s;
        let mut ttft_max = 0.0f64;
        let mut tbt_max = 0.0f64;
        for id in &state.decoding {
            let r = &state.reqs[id];
            debug_assert_eq!(r.phase, Phase::Decoding);
            if let Some(ft) = r.first_token_s {
                if ft >= cut {
                    ttft_max = ttft_max.max(ft - r.req.arrival_s);
                }
            }
            if let Some(&gap) = r.tbts.last() {
                tbt_max = tbt_max.max(gap);
            }
        }
        SignalSnapshot {
            cohort_remaining,
            waiting_mean_len,
            token_axis_expert_bytes,
            layer_axis_expert_bytes,
            window_ttft_max_s: ttft_max,
            window_tbt_max_s: tbt_max,
        }
    }
}

/// Modeled expert-load bytes to prefill `remaining` tokens on each axis
/// (paper §3 / Table 7 arithmetic, per layer × every layer): the token
/// axis pays ceil(remaining / chunk) full-stack passes of
/// covered(chunk) experts; the layer axis pays one pass of
/// covered(remaining). Returns `(token_axis, layer_axis)`; `(0, 0)` for an
/// empty cohort.
pub fn axis_expert_bytes(model: &ModelDesc, remaining: u32, chunk: u32) -> (f64, f64) {
    if remaining == 0 {
        return (0.0, 0.0);
    }
    let cov = CoverageModel::paper(model.n_experts, model.top_k);
    let per_expert = model.bytes_per_expert() as f64;
    let layers = model.n_layers as f64;
    let chunk = chunk.max(1);
    let n_chunks = remaining.div_ceil(chunk) as f64;
    let token = n_chunks * cov.covered_experts(chunk.min(remaining) as u64) * per_expert * layers;
    let layer = cov.covered_experts(remaining as u64) * per_expert * layers;
    (token, layer)
}

/// The signal-driven adaptive scheduler. See the [module docs](self).
pub struct AdaptiveScheduler {
    spec: AdaptiveSpec,
    axis: Axis,
    switches: u64,
    admission: GreedyAdmission,
    chunk_shaper: TokenChunkShaper,
    full_shaper: FullPromptShaper,
    interleave: InterleaveComposer,
    groups: LayerGroupComposer,
}

impl AdaptiveScheduler {
    pub fn new(spec: AdaptiveSpec, n_layers: u32) -> Self {
        AdaptiveScheduler {
            axis: Axis::Token,
            switches: 0,
            admission: GreedyAdmission::new(spec.max_batch),
            chunk_shaper: TokenChunkShaper::new(spec.chunk),
            full_shaper: FullPromptShaper::new(),
            interleave: InterleaveComposer::new(n_layers),
            groups: LayerGroupComposer::new(n_layers, spec.group_target),
            spec,
        }
    }

    /// The axis the CURRENT cohort runs on.
    pub fn axis(&self) -> Axis {
        self.axis
    }

    /// How many times the axis has flipped so far.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// The per-cohort decision rule. Layer axis when (a) the cohort is
    /// long enough to chunk AND the modeled token-axis expert traffic
    /// exceeds the bias threshold, or (b) the observed windowed TBT is
    /// already violating the configured target (shrink the per-iteration
    /// prefill footprint). Token axis otherwise.
    fn choose(&self, sig: &SignalSnapshot) -> Axis {
        if sig.cohort_remaining == 0 {
            return self.axis;
        }
        if sig.cohort_remaining >= self.spec.long_prompt
            && sig.token_axis_expert_bytes > self.spec.reload_bias * sig.layer_axis_expert_bytes
        {
            return Axis::Layer;
        }
        if self.spec.tbt_slo_s > 0.0 && sig.window_tbt_max_s > self.spec.tbt_slo_s {
            return Axis::Layer;
        }
        Axis::Token
    }

    fn composer_needs_unit(&self) -> bool {
        match self.axis {
            Axis::Token => self.interleave.needs_unit(),
            Axis::Layer => self.groups.needs_unit(),
        }
    }
}

impl Scheduler for AdaptiveScheduler {
    fn name(&self) -> &str {
        "adaptive"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        if self.composer_needs_unit() {
            let admitted = self.admission.admit(state);
            if !admitted.is_empty() {
                // A fresh admission cohort: re-evaluate the axis. Both
                // composers are idle here, so switching abandons nothing.
                let sig =
                    SignalSnapshot::observe(state, &admitted, self.spec.window_s, self.spec.chunk);
                let next = self.choose(&sig);
                if next != self.axis {
                    self.switches += 1;
                    self.axis = next;
                }
            }
            let unit = match self.axis {
                Axis::Token => self.chunk_shaper.shape(state, &admitted),
                Axis::Layer => self.full_shaper.shape(state, &admitted),
            };
            if !unit.is_empty() {
                match self.axis {
                    Axis::Token => self.interleave.load(unit),
                    Axis::Layer => self.groups.load(unit),
                }
            }
        }
        match self.axis {
            Axis::Token => self.interleave.compose(state),
            Axis::Layer => self.groups.compose(state),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100_000, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    fn sched() -> AdaptiveScheduler {
        AdaptiveScheduler::new(AdaptiveSpec::default(), 48)
    }

    #[test]
    fn chunking_amplifies_modeled_expert_bytes() {
        // The paper's core claim feeding the decision rule: chunking a long
        // prompt loads far more expert bytes than one layer-axis pass.
        let m = ModelDesc::qwen3_30b_a3b();
        let (token, layer) = axis_expert_bytes(&m, 8192, 512);
        assert!(token > 2.0 * layer, "token {token:.3e} vs layer {layer:.3e}");
        // A prompt inside one chunk is identical either way.
        let (token, layer) = axis_expert_bytes(&m, 300, 512);
        assert!((token - layer).abs() < 1e-6);
        assert_eq!(axis_expert_bytes(&m, 0, 512), (0.0, 0.0));
    }

    #[test]
    fn long_cohort_runs_layer_axis_short_runs_token_axis() {
        let mut s = sched();
        let mut st = state();
        st.arrive(req(1, 8192, 4));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(s.axis(), Axis::Layer);
        assert_eq!(s.switches(), 1, "started on Token, flipped to Layer");
        // Layer axis: 16 groups, one prefilling (I1), unit spans 8192.
        assert_eq!(p.groups.len(), 16);
        assert_eq!(p.prefill_groups(), 1);
        // Drain the cohort's 15 remaining groups.
        for _ in 0..15 {
            let _ = s.plan(&mut st).unwrap();
        }
        // Emulate prefill completion so the next cohort sees a clean state.
        {
            let r = st.reqs.get_mut(&1).unwrap();
            r.prefill_done = 8192;
            r.token_layers_done = 8192 * 48;
            r.generated = 1;
            r.phase = Phase::Decoding;
        }
        st.prefilling.clear();
        st.decoding.push(1);
        // A short cohort flips back to the token axis: single full-stack
        // group, whole prompt in one completing slice.
        st.arrive(req(2, 128, 4));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(s.axis(), Axis::Token);
        assert_eq!(s.switches(), 2);
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].prefill[0].tokens, 128);
        assert!(p.groups[0].prefill[0].completes);
        // I3: the ongoing decode rides in the (single) group.
        assert_eq!(p.groups[0].decode.len(), 1);
    }

    #[test]
    fn tbt_pressure_biases_toward_layer_axis() {
        let spec = AdaptiveSpec {
            tbt_slo_s: 0.03,
            ..AdaptiveSpec::default()
        };
        let mut s = AdaptiveScheduler::new(spec, 48);
        let mut st = state();
        st.now_s = 1.0;
        // A live decode whose latest gap is 50 ms: the TBT signal fires.
        st.arrive(req(9, 10, 30));
        {
            let r = st.reqs.get_mut(&9).unwrap();
            r.phase = Phase::Decoding;
            r.prefill_done = 10;
            r.generated = 3;
            r.first_token_s = Some(0.4);
            r.tbts = vec![0.01, 0.05];
        }
        st.waiting.clear();
        st.decoding.push(9);
        // A short prompt that would otherwise run the token axis.
        st.arrive(req(1, 64, 4));
        let _ = s.plan(&mut st).unwrap();
        assert_eq!(s.axis(), Axis::Layer, "TBT violation forces the layer axis");
    }

    #[test]
    fn signals_observe_queue_mix_and_live_latency() {
        let mut st = state();
        st.now_s = 20.0;
        st.arrive(req(1, 1000, 4));
        st.arrive(req(2, 3000, 4));
        // Finished records are NEVER rescanned (the signals stay bounded
        // by the live batch, not the run length) — this huge stale gap
        // must not register.
        st.arrive(req(3, 10, 2));
        {
            let r = st.reqs.get_mut(&3).unwrap();
            r.phase = Phase::Finished;
            r.first_token_s = Some(1.0);
            r.finish_s = Some(2.0);
            r.tbts = vec![0.5];
        }
        st.waiting.retain(|&id| id != 3);
        // A live decode contributes its LATEST gap and its in-window TTFT.
        st.arrive(req(4, 10, 50));
        {
            let r = st.reqs.get_mut(&4).unwrap();
            r.phase = Phase::Decoding;
            r.prefill_done = 10;
            r.generated = 3;
            r.first_token_s = Some(15.0);
            r.tbts = vec![0.2, 0.04];
        }
        st.waiting.retain(|&id| id != 4);
        st.decoding.push(4);
        let sig = SignalSnapshot::observe(&st, &[], 10.0, 512);
        assert_eq!(sig.cohort_remaining, 0);
        assert!((sig.waiting_mean_len - 2000.0).abs() < 1e-9);
        assert_eq!(
            sig.window_tbt_max_s, 0.04,
            "latest live gap, not the stale completion's 0.5"
        );
        assert!(
            (sig.window_ttft_max_s - 15.0).abs() < 1e-9,
            "live TTFT: first token at 15 s minus arrival at 0"
        );
    }
}
