//! Prefill preemption: pause admitted prefills for higher-priority work.
//!
//! The paper's layered prefill removes decode stalls, but a long prompt
//! admitted just before a short interactive request still monopolizes the
//! prefill slice budget until it completes — the interactive request's
//! TTFT absorbs the whole long prefill. [`PreemptingAdmission`] closes
//! that gap as a Policy API v2 admission WRAPPER (composition, never a
//! forked engine loop): at each unit boundary it may PAUSE in-flight
//! prefills that are outranked by a strictly higher-priority waiting
//! request, hand the freed slice budget and batch slots to the inner
//! admission policy, and RESUME the paused work at a later boundary from
//! exactly where it stopped.
//!
//! Pause semantics (see [`EngineState::pause_prefill`]):
//!
//! * KV blocks stay resident and `prefill_done` / `token_layers_done` are
//!   preserved — resume recomputes NOTHING (token·layer conservation, I2,
//!   holds across any number of pause/resume cycles);
//! * pauses happen only at unit boundaries, where the composer holds no
//!   slices — an in-progress layer-axis unit is never interrupted, so I4
//!   streaks are preserved for every composer;
//! * paused requests leave `state.prefilling`, so admission occupancy and
//!   the shapers' slice budgets no longer count them.
//!
//! No starvation: a request may spend at most `max_pauses` unit
//! boundaries paused, cumulative over its lifetime. When the budget is
//! exhausted the request is force-resumed and becomes unpausable, so
//! every admitted request finishes even under continuous high-priority
//! arrivals (locked by `tests/preemption.rs`).
//!
//! Victim order follows the fairness axis: candidates are paused in
//! descending per-tenant weighted outstanding prefill (the same
//! weighted-share notion [`crate::tenant::FairQueue`] schedules by), so
//! under multi-tenant serving the tenant holding the most weighted
//! unfinished prefill yields first.

use std::collections::BTreeMap;

use crate::sched::policy::AdmissionPolicy;
use crate::sched::state::EngineState;

/// Default cumulative pause budget (unit boundaries a request may spend
/// paused over its lifetime).
pub const MAX_PAUSES: u32 = 4;

/// Priority-preempting admission wrapper (Policy API v2
/// `preemption=pause[:budget]`). Wraps ANY admission stage — including a
/// [`FairQueue`](crate::tenant::FairQueue)-wrapped one; preemption
/// composes OUTSIDE fairness so the inner reorder still sees the full
/// waiting queue.
pub struct PreemptingAdmission {
    inner: Box<dyn AdmissionPolicy>,
    max_pauses: u32,
    /// Unit boundaries each request has spent paused (cumulative).
    spent: BTreeMap<u64, u32>,
}

impl PreemptingAdmission {
    pub fn new(inner: Box<dyn AdmissionPolicy>, max_pauses: u32) -> Self {
        PreemptingAdmission {
            inner,
            max_pauses: max_pauses.max(1),
            spent: BTreeMap::new(),
        }
    }

    /// Fair-queueing weight of a tenant: the session registry's weight
    /// (1 for untenanted requests and registry-less runs).
    fn weight(state: &EngineState, tenant: u32) -> f64 {
        match &state.tenants {
            Some(acct) if tenant != 0 => acct.registry().spec(tenant).weight.max(1) as f64,
            _ => 1.0,
        }
    }

    /// Pause every in-flight prefill outranked by the highest waiting
    /// priority, in descending per-tenant weighted-outstanding order.
    fn pause_outranked(&mut self, state: &mut EngineState) {
        let hi = state
            .waiting
            .iter()
            .map(|id| state.reqs[id].req.priority)
            .max()
            .unwrap_or(0);
        if hi == 0 {
            return;
        }
        let victims: Vec<u64> = state
            .prefilling
            .iter()
            .copied()
            .filter(|id| {
                let r = &state.reqs[id];
                r.remaining_prefill() > 0
                    && r.req.priority < hi
                    && self.spent.get(id).copied().unwrap_or(0) < self.max_pauses
            })
            .collect();
        if victims.is_empty() {
            return;
        }
        // Per-tenant weighted outstanding prefill across the victim set —
        // the FairQueue share notion, applied to who yields first.
        let mut outstanding: BTreeMap<u32, f64> = BTreeMap::new();
        for id in &victims {
            let r = &state.reqs[id];
            *outstanding.entry(r.req.tenant).or_insert(0.0) +=
                r.remaining_prefill() as f64 / Self::weight(state, r.req.tenant);
        }
        let mut ordered = victims;
        ordered.sort_by(|a, b| {
            let ra = &state.reqs[a];
            let rb = &state.reqs[b];
            outstanding[&rb.req.tenant]
                .total_cmp(&outstanding[&ra.req.tenant])
                .then(rb.remaining_prefill().cmp(&ra.remaining_prefill()))
                .then(a.cmp(b))
        });
        for id in ordered {
            state.pause_prefill(id);
        }
    }

    /// Resume paused requests that are no longer outranked, and charge one
    /// boundary of pause budget to those that stay paused. A request whose
    /// cumulative budget is exhausted is force-resumed (and, being at the
    /// budget cap, can never be paused again).
    fn resume_or_charge(&mut self, state: &mut EngineState) {
        if state.paused.is_empty() {
            return;
        }
        // A paused request is outranked while any strictly-higher-priority
        // request is still waiting OR mid-prefill — checking only the
        // waiting queue would resume victims in the same call that
        // admitted the high-priority request, handing the slice budget
        // right back.
        let threat = state
            .waiting
            .iter()
            .chain(
                state
                    .prefilling
                    .iter()
                    .filter(|id| state.reqs[id].remaining_prefill() > 0),
            )
            .map(|id| state.reqs[id].req.priority)
            .max()
            .unwrap_or(0);
        // A pause is only justified while someone else uses the freed
        // budget. If the inner policy placed nothing (e.g. the waiting
        // threat is KV-blocked behind the victims' own retained blocks)
        // and nothing decodes, holding the pause would idle the engine
        // with unfinished work — it would report a bogus drain. Resume
        // everyone; the threat re-pauses them at the next boundary once
        // it actually runs.
        let stalled = state.prefilling.is_empty() && state.decoding.is_empty();
        for id in state.paused.clone() {
            let spent = self.spent.entry(id).or_insert(0);
            let exhausted = *spent >= self.max_pauses;
            if exhausted || stalled || state.reqs[&id].req.priority >= threat {
                state.resume_prefill(id);
            } else {
                *spent += 1;
            }
        }
    }
}

impl AdmissionPolicy for PreemptingAdmission {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64> {
        self.pause_outranked(state);
        let admitted = self.inner.admit(state);
        self.resume_or_charge(state);
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDesc;
    use crate::kvcache::KvCacheManager;
    use crate::sched::policy::GreedyAdmission;
    use crate::sched::Phase;
    use crate::workload::Request;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100_000, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, priority: u8) -> Request {
        Request {
            id,
            input_len: input,
            output_len: 8,
            priority,
            ..Default::default()
        }
    }

    fn preempting(max_pauses: u32) -> PreemptingAdmission {
        PreemptingAdmission::new(Box::new(GreedyAdmission::new(256)), max_pauses)
    }

    #[test]
    fn pauses_long_prefill_for_higher_priority_arrival() {
        let mut s = state();
        s.arrive(req(1, 20_000, 0));
        let mut a = preempting(4);
        assert_eq!(a.admit(&mut s), vec![1]);
        s.reqs.get_mut(&1).unwrap().prefill_done = 512; // mid-prefill
        s.arrive(req(2, 128, 1));
        let admitted = a.admit(&mut s);
        assert_eq!(admitted, vec![2]);
        assert_eq!(s.paused, vec![1], "long prefill paused");
        assert_eq!(s.prefilling, vec![2], "interactive request has the floor");
        assert_eq!(s.reqs[&1].prefill_done, 512, "progress retained");
    }

    #[test]
    fn resumes_once_threat_clears_without_recomputation() {
        let mut s = state();
        s.arrive(req(1, 20_000, 0));
        let mut a = preempting(4);
        a.admit(&mut s);
        s.reqs.get_mut(&1).unwrap().prefill_done = 512;
        s.arrive(req(2, 128, 1));
        a.admit(&mut s);
        // The interactive prefill completes and moves to decode.
        {
            let r = s.reqs.get_mut(&2).unwrap();
            r.prefill_done = 128;
            r.phase = Phase::Decoding;
        }
        s.prefilling.clear();
        s.decoding.push(2);
        a.admit(&mut s);
        assert!(s.paused.is_empty());
        assert_eq!(s.prefilling, vec![1]);
        assert_eq!(s.reqs[&1].prefill_done, 512, "no token recomputed");
    }

    #[test]
    fn equal_priority_never_preempts() {
        let mut s = state();
        s.arrive(req(1, 20_000, 1));
        let mut a = preempting(4);
        a.admit(&mut s);
        s.arrive(req(2, 128, 1));
        a.admit(&mut s);
        assert!(s.paused.is_empty(), "same class: FCFS, no pause");
    }

    #[test]
    fn pause_budget_bounds_time_paused_and_then_protects() {
        let mut s = state();
        s.arrive(req(1, 20_000, 0));
        let mut a = preempting(2);
        a.admit(&mut s);
        s.reqs.get_mut(&1).unwrap().prefill_done = 100;
        // Continuous high-priority arrivals: a long high-priority prefill
        // is always in flight.
        s.arrive(req(2, 30_000, 1));
        a.admit(&mut s); // pause, spent -> 1
        assert_eq!(s.paused, vec![1]);
        a.admit(&mut s); // still outranked, spent -> 2
        assert_eq!(s.paused, vec![1]);
        a.admit(&mut s); // budget exhausted: force-resume
        assert!(s.paused.is_empty());
        assert_eq!(s.prefilling, vec![2, 1]);
        // And it can never be paused again.
        s.arrive(req(3, 30_000, 2));
        a.admit(&mut s);
        assert!(!s.paused.contains(&1), "exhausted budget is a shield");
    }

    #[test]
    fn kv_blocked_threat_never_strands_the_engine() {
        // The high-priority arrival cannot admit: the paused victim's
        // RETAINED blocks leave too little KV. Holding the pause would
        // leave zero runnable work (no prefilling, no decoding) and the
        // engine would declare a bogus drain — the wrapper must resume
        // the victim instead.
        let mut s = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10, 16), // 10 blocks of 16 tokens
            256,
        );
        s.arrive(req(1, 100, 0)); // 108-token footprint = 7 blocks
        let mut a = preempting(4);
        assert_eq!(a.admit(&mut s), vec![1]);
        s.arrive(req(2, 100, 1)); // needs 7 blocks, only 3 free
        let admitted = a.admit(&mut s);
        assert!(admitted.is_empty(), "threat is KV-blocked");
        assert!(s.paused.is_empty(), "stall resumes the victim");
        assert_eq!(s.prefilling, vec![1], "victim keeps running");
        assert_eq!(s.waiting, vec![2], "threat retries next boundary");
    }

    #[test]
    fn victims_yield_in_weighted_outstanding_order() {
        let mut s = state();
        s.tenants = Some(crate::tenant::TenantAccounting::new(
            crate::tenant::TenantRegistry::with_defaults(2),
        ));
        let mut a = preempting(4);
        let mut r1 = req(1, 10_000, 0);
        r1.tenant = 1;
        let mut r2 = req(2, 4_000, 0);
        r2.tenant = 2;
        s.arrive(r1);
        s.arrive(r2);
        a.admit(&mut s);
        assert_eq!(s.prefilling, vec![1, 2]);
        s.arrive(req(3, 64, 1));
        a.admit(&mut s);
        // Tenant 1 holds 10k weighted outstanding vs tenant 2's 4k: it
        // yields first (pause order = Paused event order).
        let paused_order: Vec<u64> = s
            .admissions
            .iter()
            .filter_map(|adm| match adm {
                crate::sched::state::Admission::Paused { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(paused_order, vec![1, 2]);
    }
}
