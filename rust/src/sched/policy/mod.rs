//! Policy API v2: scheduling as a composable pipeline.
//!
//! The paper's thesis is that the *scheduling axis* (tokens vs layers) is a
//! first-class design choice. The original API hard-coded that choice into
//! five closed policies behind the [`Policy`](crate::config::Policy) enum;
//! this module decomposes every policy into three orthogonal stages so new
//! operating points are a configuration, not a sixth hand-written policy:
//!
//! * [`AdmissionPolicy`] — who enters the running batch, and when
//!   (greedy FCFS, fixed run-to-completion batches, merged admission
//!   cohorts, one-at-a-time). Admission goes through
//!   [`EngineState::admit`], so KV capacity gating and prefix-cache
//!   crediting apply uniformly to every composition.
//! * [`PrefillShaper`] — how the admitted requests' remaining prefill is
//!   sliced into the next [`PrefillUnit`]: token-axis chunks, whole
//!   prompts, a cohort's full remaining work, or one request's next
//!   large chunk.
//! * [`BatchComposer`] — how a prefill unit interleaves with the ongoing
//!   decode batch across layer groups: one full-stack hybrid batch per
//!   iteration (token axis) or G contiguous layer groups with exactly one
//!   group prefilling per iteration (layer axis), enforcing I1–I4 either
//!   way.
//!
//! [`PipelineScheduler`] drives the three stages through the existing
//! [`Scheduler`] trait, so the engine core, the serve surface, and the
//! cluster layer are untouched consumers. The declarative
//! [`spec::PolicySpec`] names a composition (preset, compact string, or
//! JSON) and compiles it via [`crate::sched::build`]; each of the five
//! legacy policies is re-expressed as a canonical composition that is
//! bit-identical to its direct construction (locked by
//! `tests/policy_spec.rs`). [`adaptive::AdaptiveScheduler`] goes beyond
//! the closed set: it re-evaluates the shaper/composer choice per
//! admission cohort from live signals (prompt-length mix, the
//! `moe::traffic` expert-reload estimate, sliding-window TTFT/TBT),
//! generalizing the paper's §4.3 hybrid into a runtime policy.

pub mod adaptive;
pub mod preempt;
pub mod spec;
pub mod stages;

pub use adaptive::{AdaptiveScheduler, Axis, SignalSnapshot};
pub use preempt::PreemptingAdmission;
pub use spec::{
    AdaptiveSpec, AdmissionSpec, ComposerSpec, FairnessSpec, PolicySpec, PreemptionSpec,
    ShaperSpec,
};
pub use stages::{
    BatchAdmission, CohortAdmission, CohortShaper, FullPromptShaper, GreedyAdmission,
    InterleaveComposer, LayerGroupComposer, SizedAdmission, SoloAdmission, SoloChunkShaper,
    TokenChunkShaper,
};

use crate::sched::{EngineState, IterationPlan, PrefillWork, Scheduler};

/// One unit of prefill work produced by a [`PrefillShaper`] and consumed by
/// a [`BatchComposer`]. A token-axis composer runs the whole unit in one
/// iteration; a layer-axis composer spreads it over G iterations, one layer
/// group at a time, holding the slices fixed so each prompt token visits
/// each layer's prefill path exactly once (I2).
///
/// A slice's `completes` flag means "this unit finishes the request's
/// prompt"; the composer rewrites it per iteration (a layer-axis unit only
/// completes when its LAST group runs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PrefillUnit {
    /// Per-request prefill slices (may include zero-token completing slices
    /// for empty / fully-cached prompts, which cost nothing but let the
    /// engine emit their first token).
    pub slices: Vec<PrefillWork>,
    /// Total prompt tokens in the unit — the layer-axis composer sizes
    /// G(L) from this.
    pub tokens: u32,
}

impl PrefillUnit {
    pub fn is_empty(&self) -> bool {
        self.slices.is_empty()
    }
}

/// Stage 1: decide which waiting requests enter the running batch.
///
/// Called once per prefill-unit boundary (every iteration for token-axis
/// compositions; between units for layer-axis ones). Implementations admit
/// through [`EngineState::admit`] — which reserves KV, takes prefix-cache
/// credit, and logs `Admitted`/`KvRejected` outcomes — and return the ids
/// admitted this round, in admission order.
pub trait AdmissionPolicy: Send {
    fn admit(&mut self, state: &mut EngineState) -> Vec<u64>;
}

/// Stage 2: slice remaining prefill into the next [`PrefillUnit`].
///
/// `admitted` is the cohort stage 1 just admitted (possibly empty);
/// shapers are free to slice over the whole `state.prefilling` set instead
/// (the token-axis shapers do, so no admitted request is ever stranded).
pub trait PrefillShaper: Send {
    fn shape(&mut self, state: &EngineState, admitted: &[u64]) -> PrefillUnit;
}

/// Stage 3: interleave the current prefill unit with the decode batch
/// across layer groups, emitting one [`IterationPlan`] per iteration.
pub trait BatchComposer: Send {
    /// True when the current unit is fully consumed and the pipeline
    /// should admit + shape a new one before composing.
    fn needs_unit(&self) -> bool;
    /// Install the next unit (callers only load non-empty units, and only
    /// when [`BatchComposer::needs_unit`] is true).
    fn load(&mut self, unit: PrefillUnit);
    /// Emit this iteration's plan. Reads the decode set fresh from `state`
    /// (I3: every decoding request decodes every iteration). Returns None
    /// when there is neither prefill nor decode work.
    fn compose(&mut self, state: &EngineState) -> Option<IterationPlan>;
}

/// A [`Scheduler`] composed from the three pipeline stages. The per-plan
/// cycle is: when the composer is between units, admit (stage 1) and shape
/// (stage 2); then compose (stage 3).
pub struct PipelineScheduler {
    name: String,
    admission: Box<dyn AdmissionPolicy>,
    shaper: Box<dyn PrefillShaper>,
    composer: Box<dyn BatchComposer>,
}

impl PipelineScheduler {
    pub fn new(
        name: String,
        admission: Box<dyn AdmissionPolicy>,
        shaper: Box<dyn PrefillShaper>,
        composer: Box<dyn BatchComposer>,
    ) -> Self {
        PipelineScheduler {
            name,
            admission,
            shaper,
            composer,
        }
    }
}

impl Scheduler for PipelineScheduler {
    fn name(&self) -> &str {
        &self.name
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        if self.composer.needs_unit() {
            let admitted = self.admission.admit(state);
            let unit = self.shaper.shape(state, &admitted);
            if !unit.is_empty() {
                self.composer.load(unit);
            }
        }
        self.composer.compose(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100_000, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    #[test]
    fn preset_pipelines_report_legacy_names() {
        for p in Policy::ALL {
            let sched = PolicySpec::preset(p).build(48);
            assert_eq!(sched.name(), p.name());
        }
    }

    #[test]
    fn chunked_pipeline_plans_like_legacy_chunked() {
        // Same scenario as chunked.rs::splits_long_prompt_into_chunks, now
        // through the composed pipeline.
        let mut st = state();
        let mut s = PolicySpec::preset(Policy::Chunked).build(48);
        st.arrive(req(1, 1300, 10));
        let p1 = s.plan(&mut st).unwrap();
        assert_eq!(p1.groups.len(), 1);
        assert_eq!(p1.groups[0].prefill[0].tokens, 512);
        assert!(!p1.groups[0].prefill[0].completes);
        st.reqs.get_mut(&1).unwrap().prefill_done = 512;
        let p2 = s.plan(&mut st).unwrap();
        assert_eq!(p2.groups[0].prefill[0].pos, 512);
        st.reqs.get_mut(&1).unwrap().prefill_done = 1024;
        let p3 = s.plan(&mut st).unwrap();
        assert_eq!(p3.groups[0].prefill[0].tokens, 276);
        assert!(p3.groups[0].prefill[0].completes);
    }

    #[test]
    fn layered_pipeline_advances_one_group_per_iteration() {
        // Mirrors layered.rs::one_group_prefills_per_iteration.
        let mut st = state();
        let mut s = PolicySpec::preset(Policy::Layered).build(48);
        st.arrive(req(1, 8192, 10));
        for it in 0..16 {
            let p = s.plan(&mut st).unwrap();
            assert_eq!(p.prefill_groups(), 1, "iter {it}");
            assert_eq!(p.groups.len(), 16);
            assert_eq!(p.total_layers(), 48);
            let prefill_group = p.groups.iter().position(|g| !g.prefill.is_empty());
            assert_eq!(prefill_group, Some(it));
            assert_eq!(p.groups[it].prefill[0].completes, it == 15);
        }
    }

    #[test]
    fn custom_composition_budgeted_chunks_on_the_layer_axis() {
        // A point the old enum could not express: Sarathi-style 512-token
        // budget chunks (multi-request coalescing) scheduled on the LAYER
        // axis — each chunk-set spread over G groups.
        let spec = PolicySpec::Pipeline {
            name: None,
            admission: AdmissionSpec::Fcfs { max_batch: 256 },
            shaper: ShaperSpec::TokenChunks { chunk: 512 },
            composer: ComposerSpec::LayerGroups { target: 512 },
            fairness: FairnessSpec::None,
            preemption: PreemptionSpec::None,
        };
        let mut st = state();
        let mut s = spec.build(48);
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 300, 5));
        let p = s.plan(&mut st).unwrap();
        // 400 coalesced tokens -> one group (G = 1), both requests sliced.
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].prefill.len(), 2);
        assert!(p.groups[0].prefill.iter().all(|w| w.completes));
        // A long prompt's 512-token chunk spreads over G = 1 group per
        // 512-token unit; a 1300-token prompt takes 512+512+276.
        let mut st = state();
        let mut s = spec.build(48);
        st.arrive(req(9, 1300, 5));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill[0].tokens, 512);
        assert!(!p.groups[0].prefill[0].completes);
    }

    #[test]
    fn zero_length_prompt_completes_under_every_preset_pipeline() {
        for p in Policy::ALL {
            let mut st = state();
            let mut s = PolicySpec::preset(p).build(48);
            st.arrive(req(1, 0, 3));
            let plan = s.plan(&mut st).unwrap();
            let w = plan
                .groups
                .iter()
                .find_map(|g| g.prefill.first())
                .copied()
                .unwrap_or_else(|| panic!("{}: empty prompt unscheduled", p.name()));
            assert_eq!(w.tokens, 0, "{}", p.name());
            assert!(w.completes, "{}: empty prompt must complete", p.name());
        }
    }
}
