//! Orca-style continuous batching (iteration-level scheduling, no chunking).
//!
//! New requests are admitted at iteration boundaries and their ENTIRE prompt
//! is prefilled in one hybrid iteration alongside ongoing decodes. This
//! fixes static batching's head-of-line TTFT problem but stalls decode
//! behind long prefills (the TBT-spike failure mode chunked/layered prefill
//! were designed to remove — §2.3).
//!
//! Canonical pipeline composition (Policy API v2, bit-identical):
//! `admission=fcfs, shaper=full, composer=interleave` — see
//! [`crate::sched::policy`].

use crate::config::SchedulerConfig;
use crate::sched::{EngineState, GroupPlan, IterationPlan, PrefillWork, Scheduler};

pub struct ContinuousBatching {
    cfg: SchedulerConfig,
}

impl ContinuousBatching {
    pub fn new(cfg: SchedulerConfig) -> Self {
        ContinuousBatching { cfg }
    }
}

impl Scheduler for ContinuousBatching {
    fn name(&self) -> &str {
        "orca"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        // Admit as many waiting requests as capacity allows.
        while let Some(&head) = state.waiting.first() {
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.cfg.max_batch) {
                break;
            }
            if !state.admit(head) {
                break;
            }
        }

        // Whole-prompt prefill for everything admitted this iteration. A
        // request with zero remaining prefill (empty prompt) still gets a
        // zero-token completing slice — skipping it would strand it in
        // Prefilling forever.
        let mut prefill = Vec::new();
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            prefill.push(PrefillWork {
                req: id,
                tokens: r.remaining_prefill(),
                pos: r.prefill_done,
                completes: true,
            });
        }

        let decode = state.decode_set();
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(IterationPlan {
            groups: vec![GroupPlan {
                n_layers: state.model.n_layers,
                prefill,
                decode,
            }],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn req(id: u64, input: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: 10,
            ..Default::default()
        }
    }

    #[test]
    fn zero_length_prompt_gets_completing_slice() {
        let mut s = ContinuousBatching::new(SchedulerConfig::preset(Policy::Orca));
        let mut st = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        st.arrive(req(1, 0));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill[0].tokens, 0);
        assert!(p.groups[0].prefill[0].completes);
    }

    #[test]
    fn whole_prompt_in_one_iteration() {
        let mut s = ContinuousBatching::new(SchedulerConfig::preset(Policy::Orca));
        let mut st = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        st.arrive(req(1, 9000));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill[0].tokens, 9000);
        assert!(p.groups[0].prefill[0].completes);
    }

    #[test]
    fn admits_multiple_up_to_cap() {
        let mut cfg = SchedulerConfig::preset(Policy::Orca);
        cfg.max_batch = 2;
        let mut s = ContinuousBatching::new(cfg);
        let mut st = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        st.arrive(req(1, 100));
        st.arrive(req(2, 100));
        st.arrive(req(3, 100));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill.len(), 2);
        assert_eq!(st.waiting, vec![3]);
    }
}
