//! Plan-level audit of the normative scheduler invariants I1–I4 (see the
//! module comment in `sched/mod.rs`):
//!
//!  I1  at most one group performs prefill per iteration;
//!  I2  a prompt token visits each layer's prefill path exactly once
//!      (token·layer conservation: exactly input_len × n_layers at
//!      completion, never more along the way);
//!  I3  every running decode request decodes exactly once per iteration
//!      (scheduled in every plan, in groups tiling the full layer stack);
//!  I4  a layer-axis admission (layered cohort / hybrid chunk) completes in
//!      exactly G consecutive iterations, where G is its group count.
//!
//! [`drive_to_drain`] steps a scheduler pipeline over a request set with
//! emulated engine effects (mirroring `engine::EngineCore::advance`) and
//! checks all four laws on every plan plus conservation at drain. It is the
//! single source of the laws: the `sched::properties` suite drives it over
//! random (trace, policy) pairs, and the chaos harness
//! ([`crate::harness::invariants`]) drives it over every policy a fuzzed
//! scenario names.

use std::collections::BTreeMap;

use crate::config::{ModelDesc, SchedulerConfig};
use crate::kvcache::KvCacheManager;
use crate::sched::{self, EngineState, Phase};
use crate::workload::Request;
use crate::{prop_assert, prop_assert_eq};

/// Iteration budget before the drive declares a livelock.
pub const MAX_ITERS: usize = 5_000;

/// Drive one (request set, scheduler config) pair to drain, checking I1–I4
/// on every plan and conservation at the end. `arrivals` pairs each request
/// with the iteration index at which it arrives (plan-level audits have no
/// clock; staggering exercises mid-run admission). Returns the first
/// violated law as an error string.
pub fn drive_to_drain(
    cfg: &SchedulerConfig,
    model: &ModelDesc,
    arrivals: &[(Request, usize)],
) -> Result<(), String> {
    let n_layers = model.n_layers;
    let mut state = EngineState::new(model.clone(), KvCacheManager::new(200_000, 16), 64);
    let mut policy = sched::build(cfg, n_layers);
    let mut pending: Vec<(Request, usize)> = arrivals.to_vec();

    // I4 streak tracking: (prefill ids, pos of first slice) -> group count
    // of those plans and iterations seen so far.
    let mut streak: Option<((Vec<u64>, u32), u32, u32)> = None;
    let mut iter = 0usize;
    loop {
        // Deliver arrivals scheduled for this iteration index.
        pending.retain(|(r, due)| {
            if *due <= iter {
                state.arrive(*r);
                false
            } else {
                true
            }
        });

        let Some(plan) = policy.plan(&mut state) else {
            if pending.is_empty() {
                break;
            }
            iter += 1; // idle until the next staggered arrival
            prop_assert!(iter < MAX_ITERS, "idle livelock");
            continue;
        };
        iter += 1;
        prop_assert!(iter < MAX_ITERS, "scheduler did not drain");

        // I1: at most one group prefills.
        prop_assert!(
            plan.prefill_groups() <= 1,
            "I1: {} prefill groups ({})",
            plan.prefill_groups(),
            policy.name()
        );
        // Groups tile the full layer stack.
        prop_assert_eq!(plan.total_layers(), n_layers);

        // I3: every group carries the identical decode set, so each decoding
        // request traverses exactly n_layers; and nobody is left out.
        let first_set: Vec<u64> = plan.groups[0].decode.iter().map(|&(id, _)| id).collect();
        for gr in &plan.groups {
            let set: Vec<u64> = gr.decode.iter().map(|&(id, _)| id).collect();
            prop_assert_eq!(&set, &first_set);
        }
        for id in &state.decoding {
            prop_assert!(
                first_set.contains(id),
                "I3: decoding req {id} unscheduled ({})",
                policy.name()
            );
        }

        // I4: a layer-axis prefill streak — same (ids, pos) across
        // consecutive plans — lasts exactly as many iterations as the plan
        // has groups. Token-axis policies emit single-group plans, so every
        // streak is trivially 1-of-1.
        let prefill_ids: Vec<u64> = plan
            .groups
            .iter()
            .flat_map(|gr| gr.prefill.iter().map(|w| w.req))
            .collect();
        let completes = plan
            .groups
            .iter()
            .any(|gr| gr.prefill.iter().any(|w| w.completes));
        if prefill_ids.is_empty() {
            prop_assert!(streak.is_none(), "I4: streak interrupted by idle plan");
        } else {
            let pos0 = plan
                .groups
                .iter()
                .find_map(|gr| gr.prefill.first())
                .map(|w| w.pos)
                .unwrap();
            let key = (prefill_ids, pos0);
            let g_expected = plan.groups.len() as u32;
            match &mut streak {
                Some((k, exp, seen)) if *k == key => {
                    prop_assert_eq!(*exp, g_expected);
                    *seen += 1;
                }
                Some(_) => {
                    // A new slice may only start after the previous streak
                    // wrapped its groups (cleared below) — changing slices
                    // mid-streak abandons prefill work.
                    return Err("I4: prefill streak changed before completing".into());
                }
                None => streak = Some((key, g_expected, 1)),
            }
            let (_, exp, seen) = streak.as_ref().unwrap();
            prop_assert!(seen <= exp, "I4: streak of {seen} exceeds G={exp}");
            if completes {
                // Prompt done: the slice must have taken exactly G plans.
                prop_assert_eq!(*seen, *exp);
            }
            if seen == exp {
                // Streak wrapped its group cursor (chunked/orca/static wrap
                // every iteration, G = 1); the next slice starts fresh.
                streak = None;
            }
        }

        // ---- emulate engine effects (mirrors EngineCore::advance) ----
        let mut per_req: BTreeMap<u64, (u32, u32, bool)> = BTreeMap::new();
        for gr in &plan.groups {
            for w in &gr.prefill {
                let e = per_req.entry(w.req).or_insert((w.tokens, 0, false));
                e.1 += gr.n_layers;
                e.2 |= w.completes;
            }
        }
        let mut done_prefills = Vec::new();
        for (id, (tokens, layer_sum, w_completes)) in per_req {
            let r = state.reqs.get_mut(&id).unwrap();
            r.token_layers_done += tokens as u64 * layer_sum as u64;
            // I2: never exceed input_len × n_layers.
            prop_assert!(
                r.token_layers_done <= r.req.input_len as u64 * n_layers as u64,
                "I2: req {id} over-prefilled ({})",
                policy.name()
            );
            if w_completes {
                // I2: exactly input_len × n_layers at completion.
                prop_assert_eq!(
                    r.token_layers_done,
                    r.req.input_len as u64 * n_layers as u64
                );
                r.prefill_done = r.req.input_len;
                done_prefills.push(id);
            } else {
                r.prefill_done = (r.token_layers_done / n_layers as u64) as u32;
            }
        }
        for id in done_prefills {
            let r = state.reqs.get_mut(&id).unwrap();
            r.generated = 1;
            state.prefilling.retain(|&x| x != id);
            if r.done_decoding() {
                r.phase = Phase::Finished;
                let _ = state.kv.release(id);
            } else {
                r.phase = Phase::Decoding;
                state.decoding.push(id);
            }
        }
        // Exactly the plan's decode set emits tokens (I3: that set is every
        // request that was decoding at plan time).
        for id in first_set {
            let r = state.reqs.get_mut(&id).unwrap();
            if r.done_decoding() {
                continue;
            }
            r.generated += 1;
            if r.done_decoding() {
                r.phase = Phase::Finished;
                state.decoding.retain(|&x| x != id);
                let _ = state.kv.release(id);
            }
        }
    }

    // Conservation at drain: every request finished with exactly its
    // output budget and a fully-prefilled prompt.
    for (id, r) in state.reqs.iter() {
        prop_assert!(
            r.phase == Phase::Finished,
            "req {id} not finished ({})",
            policy.name()
        );
        prop_assert_eq!(r.generated, r.req.output_len.max(1));
        prop_assert_eq!(r.prefill_done, r.req.input_len);
        prop_assert_eq!(
            r.token_layers_done,
            r.req.input_len as u64 * n_layers as u64
        );
    }
    Ok(())
}
