//! Chunked prefill (Sarathi-Serve, the paper's primary baseline).
//!
//! Token-axis scheduling: each iteration forms one *hybrid batch* = all
//! ongoing decodes + up to `chunk_size` prompt tokens taken FCFS from
//! admitted prefills, executed through ALL layers. Long prompts therefore
//! traverse the full layer stack once per chunk — the source of the MoE
//! expert-reload amplification the paper eliminates (§3).
//!
//! Canonical pipeline composition (Policy API v2, bit-identical):
//! `admission=fcfs, shaper=chunks:512, composer=interleave` — see
//! [`crate::sched::policy`].

use crate::config::SchedulerConfig;
use crate::sched::{EngineState, GroupPlan, IterationPlan, PrefillWork, Scheduler};

pub struct ChunkedPrefill {
    cfg: SchedulerConfig,
}

impl ChunkedPrefill {
    pub fn new(cfg: SchedulerConfig) -> Self {
        ChunkedPrefill { cfg }
    }

    /// Admit waiting requests while the engine has decode slots + KV room.
    fn admit_waiting(&self, state: &mut EngineState) {
        while let Some(&head) = state.waiting.first() {
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.cfg.max_batch) {
                break;
            }
            if !state.admit(head) {
                break; // KV full: FCFS head-of-line blocks (no bypass)
            }
        }
    }
}

impl Scheduler for ChunkedPrefill {
    fn name(&self) -> &str {
        "chunked"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        self.admit_waiting(state);

        let decode = state.decode_set();

        // Fill the chunk token budget FCFS across admitted prefills
        // (Sarathi coalesces short requests into one chunk).
        let mut budget = self.cfg.chunk_size;
        let mut prefill = Vec::new();
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            let remaining = r.remaining_prefill();
            if remaining == 0 {
                // Zero remaining prefill (empty prompt): silently skipping
                // used to strand the request in Prefilling forever. Emit a
                // zero-token completing slice — costs nothing, consumes no
                // budget, and lets the engine emit its first token.
                prefill.push(PrefillWork {
                    req: id,
                    tokens: 0,
                    pos: r.prefill_done,
                    completes: true,
                });
                continue;
            }
            if budget == 0 {
                continue;
            }
            let take = remaining.min(budget);
            prefill.push(PrefillWork {
                req: id,
                tokens: take,
                pos: r.prefill_done,
                completes: take == remaining,
            });
            budget -= take;
        }

        if prefill.is_empty() && decode.is_empty() {
            return None;
        }

        // Token-axis policy: one group spanning the whole layer stack.
        Some(IterationPlan {
            groups: vec![GroupPlan {
                n_layers: state.model.n_layers,
                prefill,
                decode,
            }],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn setup(chunk: u32) -> (ChunkedPrefill, EngineState) {
        let mut cfg = SchedulerConfig::preset(Policy::Chunked);
        cfg.chunk_size = chunk;
        let state = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        (ChunkedPrefill::new(cfg), state)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    #[test]
    fn zero_length_prompt_gets_completing_slice() {
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 0, 3));
        let p = s.plan(&mut st).unwrap();
        let w = p.groups[0].prefill[0];
        assert_eq!(w.tokens, 0);
        assert!(w.completes, "empty prompt must complete, not strand");
    }

    #[test]
    fn zero_remaining_completes_even_with_budget_exhausted() {
        // A long prompt eats the whole chunk budget; the empty prompt
        // behind it must still complete this iteration.
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 4096, 5));
        st.arrive(req(2, 0, 3));
        let p = s.plan(&mut st).unwrap();
        let zero = p.groups[0]
            .prefill
            .iter()
            .find(|w| w.req == 2)
            .expect("empty prompt scheduled");
        assert!(zero.completes);
        let long = p.groups[0].prefill.iter().find(|w| w.req == 1).unwrap();
        assert_eq!(long.tokens, 512);
    }

    #[test]
    fn splits_long_prompt_into_chunks() {
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 1300, 10));
        let p1 = s.plan(&mut st).unwrap();
        assert_eq!(p1.groups.len(), 1);
        assert_eq!(p1.groups[0].prefill[0].tokens, 512);
        assert!(!p1.groups[0].prefill[0].completes);
        // Engine would update progress; emulate it.
        st.reqs.get_mut(&1).unwrap().prefill_done = 512;
        let p2 = s.plan(&mut st).unwrap();
        assert_eq!(p2.groups[0].prefill[0].pos, 512);
        st.reqs.get_mut(&1).unwrap().prefill_done = 1024;
        let p3 = s.plan(&mut st).unwrap();
        assert_eq!(p3.groups[0].prefill[0].tokens, 276);
        assert!(p3.groups[0].prefill[0].completes);
    }

    #[test]
    fn coalesces_small_prompts_into_one_chunk() {
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 200, 5));
        st.arrive(req(3, 300, 5));
        let p = s.plan(&mut st).unwrap();
        let pf = &p.groups[0].prefill;
        // 100 + 200 fill 300; then 212 of request 3.
        assert_eq!(pf.len(), 3);
        assert_eq!(pf[0].tokens, 100);
        assert!(pf[0].completes);
        assert_eq!(pf[1].tokens, 200);
        assert!(pf[1].completes);
        assert_eq!(pf[2].tokens, 212);
        assert!(!pf[2].completes);
        let total: u32 = pf.iter().map(|w| w.tokens).sum();
        assert_eq!(total, 512);
    }

    #[test]
    fn decode_only_plan_when_no_prefill() {
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 10, 5));
        st.admit(1);
        let r = st.reqs.get_mut(&1).unwrap();
        r.prefill_done = 10;
        r.generated = 1;
        r.phase = crate::sched::Phase::Decoding;
        st.prefilling.clear();
        st.decoding.push(1);
        let p = s.plan(&mut st).unwrap();
        assert!(p.groups[0].prefill.is_empty());
        assert_eq!(p.groups[0].decode.len(), 1);
    }

    #[test]
    fn none_when_idle() {
        let (mut s, mut st) = setup(512);
        assert!(s.plan(&mut st).is_none());
    }

    #[test]
    fn single_group_spans_all_layers() {
        let (mut s, mut st) = setup(512);
        st.arrive(req(1, 600, 5));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.total_layers(), st.model.n_layers);
        assert_eq!(p.groups.len(), 1);
    }
}
