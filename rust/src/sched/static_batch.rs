//! Static (FasterTransformer-style) batching: fixed batches processed
//! run-to-completion. New arrivals wait for the whole batch to finish —
//! stall-free decode and stable TBT, but TTFT inflates with batch makespan
//! (§2.3). Included as the historical baseline.
//!
//! Canonical pipeline composition (Policy API v2, bit-identical):
//! `admission=batch:16, shaper=full, composer=interleave` — see
//! [`crate::sched::policy`].

use crate::config::SchedulerConfig;
use crate::sched::{EngineState, GroupPlan, IterationPlan, PrefillWork, Scheduler};

pub struct StaticBatching {
    cfg: SchedulerConfig,
    /// The in-flight batch; no admissions until it fully drains.
    batch: Vec<u64>,
}

impl StaticBatching {
    pub fn new(cfg: SchedulerConfig) -> Self {
        StaticBatching {
            cfg,
            batch: Vec::new(),
        }
    }

    fn batch_done(&self, state: &EngineState) -> bool {
        self.batch.iter().all(|id| {
            state
                .reqs
                .get(id)
                .map(|r| r.phase == crate::sched::Phase::Finished)
                .unwrap_or(true)
        })
    }
}

impl Scheduler for StaticBatching {
    fn name(&self) -> &str {
        "static"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        if self.batch_done(state) {
            self.batch.clear();
            // Form the next fixed batch.
            while self.batch.len() < self.cfg.static_batch {
                let Some(&head) = state.waiting.first() else {
                    break;
                };
                if !state.admit(head) {
                    break;
                }
                self.batch.push(head);
            }
            if self.batch.is_empty() {
                // No batch to form — but adopted (migrated) decoding
                // requests live outside any batch and must still decode
                // every iteration (I3), so fall through to a decode-only
                // plan instead of stalling them.
                let decode = state.decode_set();
                if decode.is_empty() {
                    return None;
                }
                return Some(IterationPlan {
                    groups: vec![GroupPlan {
                        n_layers: state.model.n_layers,
                        prefill: Vec::new(),
                        decode,
                    }],
                });
            }
        }

        // Phase 1: prefill every batch member (single big iteration each).
        // Zero-remaining members (empty prompts) get a zero-token completing
        // slice rather than being stranded in Prefilling.
        let mut prefill = Vec::new();
        for &id in &state.prefilling {
            let r = &state.reqs[&id];
            prefill.push(PrefillWork {
                req: id,
                tokens: r.remaining_prefill(),
                pos: r.prefill_done,
                completes: true,
            });
        }
        let decode = state.decode_set();
        if prefill.is_empty() && decode.is_empty() {
            return None;
        }
        Some(IterationPlan {
            groups: vec![GroupPlan {
                n_layers: state.model.n_layers,
                prefill,
                decode,
            }],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::sched::Phase;
    use crate::workload::Request;

    fn req(id: u64) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: 100,
            output_len: 4,
            ..Default::default()
        }
    }

    #[test]
    fn zero_length_prompt_gets_completing_slice() {
        let mut cfg = SchedulerConfig::preset(Policy::Static);
        cfg.static_batch = 2;
        let mut s = StaticBatching::new(cfg);
        let mut st = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        let mut r = req(1);
        r.input_len = 0;
        st.arrive(r);
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill[0].tokens, 0);
        assert!(p.groups[0].prefill[0].completes);
    }

    #[test]
    fn no_admission_until_batch_drains() {
        let mut cfg = SchedulerConfig::preset(Policy::Static);
        cfg.static_batch = 2;
        let mut s = StaticBatching::new(cfg);
        let mut st = EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(10_000, 16),
            256,
        );
        st.arrive(req(1));
        st.arrive(req(2));
        st.arrive(req(3));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill.len(), 2);
        assert_eq!(st.waiting, vec![3]);
        // Batch members still active -> request 3 keeps waiting.
        for id in [1u64, 2] {
            let r = st.reqs.get_mut(&id).unwrap();
            r.prefill_done = 100;
            r.generated = 1;
            r.phase = Phase::Decoding;
        }
        st.prefilling.clear();
        st.decoding = vec![1, 2];
        let _ = s.plan(&mut st).unwrap();
        assert_eq!(st.waiting, vec![3]);
        // Finish the batch; next plan admits request 3.
        for id in [1u64, 2] {
            st.reqs.get_mut(&id).unwrap().phase = Phase::Finished;
        }
        st.decoding.clear();
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups[0].prefill.len(), 1);
        assert_eq!(p.groups[0].prefill[0].req, 3);
    }
}
