//! Scheduling: the paper's contribution (layered prefill) plus the baselines
//! it is evaluated against (chunked prefill / Orca continuous batching /
//! static batching) and the §4.3 hybrid generalization.
//!
//! A `Scheduler` plans one engine iteration at a time over mutable
//! `EngineState`. The plan is expressed per *layer group* so that layer-axis
//! policies are first-class: token-axis policies simply emit a single group
//! covering all layers.
//!
//! Two ways to get a scheduler, one [`build`] entry point:
//!
//! * the legacy [`Policy`] enum — five closed presets, constructed
//!   directly (the [`chunked`] / [`orca`] / [`static_batch`] /
//!   [`layered`] / [`hybrid`] modules);
//! * **Policy API v2** ([`policy`]) — a composable pipeline
//!   (admission → prefill shaping → batch composition) declared by a
//!   [`policy::PolicySpec`] (preset name, compact string, or JSON) and
//!   compiled through the same `Scheduler` trait object. Every preset is
//!   re-expressed as a canonical composition (bit-identity-locked by
//!   `tests/policy_spec.rs`), and [`policy::AdaptiveScheduler`] chooses
//!   the scheduling axis per admission cohort from live signals.
//!
//! Normative invariants (checked by property tests over BOTH surfaces):
//!  I1  at most one group performs prefill per iteration (layered);
//!  I2  a prompt token visits each layer's prefill path exactly once;
//!  I3  every running decode request decodes exactly once per iteration;
//!  I4  a layer-axis admission unit completes in exactly G iterations.

pub mod chunked;
pub mod hybrid;
pub mod layered;
pub mod orca;
pub mod policy;
pub mod static_batch;
pub mod audit;
pub mod state;

#[cfg(test)]
mod properties;

pub use policy::PolicySpec;
pub use state::{Admission, EngineState, Phase, ReqTable, SimReq};

use crate::config::{Policy, SchedulerConfig};

/// Prefill work for one request within one layer group this iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefillWork {
    pub req: u64,
    /// Number of prompt tokens processed through this group's layers.
    pub tokens: u32,
    /// Absolute position of the slice's first token (context already cached
    /// *in these layers* before the slice).
    pub pos: u32,
    /// True if this work completes the request's prefill (first token is
    /// emitted at the end of this iteration).
    pub completes: bool,
}

/// One layer group's work within an iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GroupPlan {
    /// Number of contiguous layers in this group.
    pub n_layers: u32,
    /// Prefill slices co-scheduled on this group (empty for decode-only).
    pub prefill: Vec<PrefillWork>,
    /// Requests decoding through this group (context length at plan time).
    pub decode: Vec<(u64, u32)>,
}

/// Complete plan for one engine iteration.
#[derive(Clone, Debug, Default)]
pub struct IterationPlan {
    pub groups: Vec<GroupPlan>,
}

impl IterationPlan {
    pub fn total_layers(&self) -> u32 {
        self.groups.iter().map(|g| g.n_layers).sum()
    }

    pub fn has_work(&self) -> bool {
        self.groups
            .iter()
            .any(|g| !g.prefill.is_empty() || !g.decode.is_empty())
    }

    pub fn prefill_groups(&self) -> usize {
        self.groups.iter().filter(|g| !g.prefill.is_empty()).count()
    }
}

/// A scheduling policy: plans the next iteration over engine state.
/// Returns None when it has nothing to run (engine then advances time to
/// the next arrival).
///
/// `name` is the policy's display name, surfaced per replica in
/// `SessionReport::policies` and the CLI tables (legacy presets return
/// their enum name; spec-compiled pipelines return the spec's name).
pub trait Scheduler: Send {
    fn name(&self) -> &str;
    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan>;
}

/// Build a scheduler from config. A config carrying a
/// [`policy::PolicySpec`] (see [`SchedulerConfig::spec`]) compiles the
/// spec's pipeline — the spec's own knobs govern, not the legacy fields;
/// otherwise the legacy [`Policy`] preset is constructed directly. The two
/// paths are bit-identical for every preset (locked by
/// `tests/policy_spec.rs`).
pub fn build(config: &SchedulerConfig, n_layers: u32) -> Box<dyn Scheduler> {
    if let Some(spec) = &config.spec {
        return spec.build(n_layers);
    }
    match config.policy {
        Policy::Static => Box::new(static_batch::StaticBatching::new(config.clone())),
        Policy::Orca => Box::new(orca::ContinuousBatching::new(config.clone())),
        Policy::Chunked => Box::new(chunked::ChunkedPrefill::new(config.clone())),
        Policy::Layered => Box::new(layered::LayeredPrefill::new(config.clone(), n_layers)),
        Policy::Hybrid => Box::new(hybrid::HybridChunkedLayered::new(config.clone(), n_layers)),
    }
}

/// Partition `n_layers` into `g` contiguous groups with sizes differing by
/// at most one (paper §4.1; future-work note on non-divisible counts).
/// `g` is clamped to `[1, n_layers]`.
///
/// A zero-layer model partitions into the EMPTY group list — there is
/// nothing to schedule, and callers iterate over no groups — rather than
/// the former silent `[0]` single empty group the `max(1)` clamp produced.
pub fn partition_layers(n_layers: u32, g: u32) -> Vec<u32> {
    if n_layers == 0 {
        return Vec::new();
    }
    let g = g.clamp(1, n_layers);
    let base = n_layers / g;
    let extra = n_layers % g;
    (0..g)
        .map(|i| base + if i < extra { 1 } else { 0 })
        .collect()
}

/// Paper §4.4: number of layer groups for a prompt of length `len`,
/// targeting per-iteration prefill work comparable to a `target`-token
/// chunk: G(L) = ceil(L / target) for L > 0.
///
/// G(0) = 0 — zero remaining prefill needs ZERO prefill iterations. The
/// former `max(1)` clamp reported one group for an empty prompt, which made
/// layer-axis policies plan a zero-token chunk as if it were real work.
/// Callers that still need a group partition for a zero-work admission
/// (an empty prompt must complete through SOME iteration so the engine can
/// emit its first token) rely on [`partition_layers`] clamping `g = 0` to a
/// single full-stack group.
pub fn groups_for_len(len: u32, target: u32) -> u32 {
    len.div_ceil(target.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_layers() {
        for n in [1u32, 7, 8, 48] {
            for g in 1..=n {
                let p = partition_layers(n, g);
                assert_eq!(p.iter().sum::<u32>(), n);
                assert_eq!(p.len(), g as usize);
                let mx = *p.iter().max().unwrap();
                let mn = *p.iter().min().unwrap();
                assert!(mx - mn <= 1, "n={n} g={g} p={p:?}");
            }
        }
    }

    #[test]
    fn partition_clamps_excess_groups() {
        let p = partition_layers(4, 9);
        assert_eq!(p.len(), 4);
        assert!(p.iter().all(|&x| x == 1));
    }

    #[test]
    fn partition_zero_layers_is_explicitly_empty() {
        // No layers -> no groups (documented), never a silent [0] group.
        for g in [0u32, 1, 5, 100] {
            assert_eq!(partition_layers(0, g), Vec::<u32>::new());
        }
        // And g = 0 on a real stack still yields one full-stack group.
        assert_eq!(partition_layers(7, 0), vec![7]);
    }

    #[test]
    fn groups_for_len_matches_paper() {
        // Paper §4.4: L=8192 -> G=16; L=512 -> G=1 (target 512).
        assert_eq!(groups_for_len(8192, 512), 16);
        assert_eq!(groups_for_len(512, 512), 1);
        assert_eq!(groups_for_len(513, 512), 2);
        assert_eq!(groups_for_len(1, 512), 1);
    }

    #[test]
    fn groups_for_len_degenerate_inputs() {
        // G(0) = 0: no remaining prefill means no prefill iterations — the
        // former max(1) clamp planned a zero-token chunk for empty prompts.
        assert_eq!(groups_for_len(0, 512), 0);
        assert_eq!(groups_for_len(0, 1), 0);
        assert_eq!(groups_for_len(0, 0), 0);
        // Zero target clamps to per-token grouping instead of dividing by 0.
        assert_eq!(groups_for_len(5, 0), 5);
        // And the partition clamp turns a zero-group request into a single
        // full-stack group, the shape zero-work admissions complete through.
        assert_eq!(partition_layers(48, groups_for_len(0, 512)), vec![48]);
    }

    #[test]
    fn plan_helpers() {
        let mut p = IterationPlan::default();
        assert!(!p.has_work());
        p.groups.push(GroupPlan {
            n_layers: 4,
            prefill: vec![],
            decode: vec![(1, 10)],
        });
        p.groups.push(GroupPlan {
            n_layers: 4,
            prefill: vec![PrefillWork {
                req: 2,
                tokens: 64,
                pos: 0,
                completes: false,
            }],
            decode: vec![(1, 10)],
        });
        assert!(p.has_work());
        assert_eq!(p.total_layers(), 8);
        assert_eq!(p.prefill_groups(), 1);
    }
}
