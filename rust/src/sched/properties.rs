//! Property tests for the normative scheduler invariants I1–I4 (see the
//! module comment in `sched/mod.rs`), checked at the PLAN level over random
//! traces × all five policies with emulated engine effects:
//!
//!  I1  at most one group performs prefill per iteration;
//!  I2  a prompt token visits each layer's prefill path exactly once
//!      (token·layer conservation: exactly input_len × n_layers at
//!      completion, never more along the way);
//!  I3  every running decode request decodes exactly once per iteration
//!      (scheduled in every plan, in groups tiling the full layer stack);
//!  I4  a layer-axis admission (layered cohort / hybrid chunk) completes in
//!      exactly G consecutive iterations, where G is its group count.
//!
//! The plan auditor and engine emulation live in [`crate::sched::audit`]
//! (shared with the chaos harness); this suite drives it over random
//! (trace, policy) pairs.
//!
//! Coverage spans BOTH scheduler surfaces: legacy direct constructions,
//! their canonical Policy-API-v2 compositions, random novel pipeline
//! compositions (any admission × shaper × composer), and the adaptive
//! policy — I1–I4 are invariants of the pipeline contracts, not of the
//! five presets.

use crate::config::{ModelDesc, Policy, SchedulerConfig};
use crate::kvcache::KvCacheManager;
use crate::sched::policy::{
    AdaptiveSpec, AdmissionSpec, ComposerSpec, FairnessSpec, PolicySpec, PreemptionSpec,
    ShaperSpec,
};
use crate::sched::{self, EngineState};
use crate::util::proptest::{check, Gen, PropResult};
use crate::workload::Request;
use crate::prop_assert_eq;

const POLICIES: [Policy; 5] = [
    Policy::Static,
    Policy::Orca,
    Policy::Chunked,
    Policy::Layered,
    Policy::Hybrid,
];

/// Random request set with arrival "times" expressed in iteration indices
/// (plan-level tests have no clock; staggering exercises mid-run admission).
fn random_requests(g: &mut Gen) -> Vec<(u64, Request, usize)> {
    let n = g.usize(1, 8);
    (0..n as u64)
        .map(|id| {
            let r = Request {
                id,
                arrival_s: 0.0,
                // Degenerate inputs included: empty prompts (input_len 0)
                // must drain under every policy (zero-token completing
                // slices), not strand in Prefilling.
                input_len: g.usize(0, 16_000) as u32,
                output_len: g.usize(1, 12) as u32,
                // Priority classes (inert without a preemption stage): mixed
                // classes exercise pause/resume under preempting pipelines.
                priority: g.usize(0, 2) as u8,
                ..Default::default()
            };
            (id, r, g.usize(0, 25))
        })
        .collect()
}

/// A random novel pipeline: any admission × any shaper × any composer.
/// Every combination is strand-free by construction (token-axis shapers
/// sweep the whole prefilling set; the solo shaper sweeps zero-remaining
/// leftovers), so I1–I4 must hold for all of them.
fn random_pipeline(g: &mut Gen) -> PolicySpec {
    let admission = match g.usize(0, 5) {
        0 => AdmissionSpec::Fcfs { max_batch: 64 },
        1 => AdmissionSpec::Batch {
            batch_size: g.usize(1, 8),
        },
        2 => AdmissionSpec::Cohort {
            max_batch: 64,
            merge: g.bool(),
            merge_target: 512,
        },
        3 => AdmissionSpec::Srpf { max_batch: 64 },
        4 => AdmissionSpec::Srpt { max_batch: 64 },
        _ => AdmissionSpec::Solo { max_batch: 64 },
    };
    let shaper = match g.usize(0, 3) {
        0 => ShaperSpec::TokenChunks {
            chunk: *g.pick(&[128u32, 512, 1024]),
        },
        1 => ShaperSpec::FullPrompt,
        2 => ShaperSpec::CohortUnit,
        _ => ShaperSpec::SoloChunk {
            chunk: *g.pick(&[1024u32, 4096]),
        },
    };
    let composer = if g.bool() {
        ComposerSpec::Interleave
    } else {
        ComposerSpec::LayerGroups {
            target: *g.pick(&[128u32, 512]),
        }
    };
    // Preemption composes over any admission: pause/resume must preserve
    // I1–I4 and conservation for every stage combination.
    let preemption = if g.bool() {
        PreemptionSpec::Pause {
            max_pauses: g.usize(1, 4) as u32,
        }
    } else {
        PreemptionSpec::None
    };
    PolicySpec::Pipeline {
        name: None,
        admission,
        shaper,
        composer,
        fairness: FairnessSpec::None,
        preemption,
    }
}

fn random_config(g: &mut Gen) -> SchedulerConfig {
    let policy = *g.pick(&POLICIES);
    let mut cfg = SchedulerConfig::preset(policy);
    cfg.chunk_size = *g.pick(&[128u32, 512, 1024]);
    cfg.group_token_target = *g.pick(&[128u32, 512]);
    cfg.hybrid_chunk_size = *g.pick(&[1024u32, 4096]);
    cfg.static_batch = g.usize(1, 8);
    // Both scheduler surfaces: legacy direct construction, the same
    // config's canonical pipeline composition, a random novel pipeline,
    // or the adaptive policy.
    match g.usize(0, 3) {
        0 => {}
        1 => cfg.spec = Some(PolicySpec::from_config(&cfg)),
        2 => cfg.spec = Some(random_pipeline(g)),
        _ => {
            cfg.spec = Some(PolicySpec::Adaptive(AdaptiveSpec {
                max_batch: 64,
                chunk: *g.pick(&[128u32, 512]),
                group_target: *g.pick(&[128u32, 512]),
                long_prompt: *g.pick(&[256u32, 1024, 4096]),
                window_s: 5.0,
                ..AdaptiveSpec::default()
            }));
        }
    }
    cfg
}

/// Drive one random (trace, policy) pair to drain via the shared
/// plan-level auditor ([`crate::sched::audit`]), which checks I1-I4 on
/// every plan and conservation at the end.
fn drive(g: &mut Gen) -> PropResult {
    let model = ModelDesc::qwen3_30b_a3b();
    let cfg = random_config(g);
    let arrivals: Vec<(Request, usize)> = random_requests(g)
        .into_iter()
        .map(|(_, r, due)| (r, due))
        .collect();
    sched::audit::drive_to_drain(&cfg, &model, &arrivals)
}

#[test]
fn prop_invariants_i1_i4_all_policies() {
    check("sched invariants I1-I4 x all policies", 60, drive);
}

#[test]
fn prop_layered_cohort_group_counts_match_prompt_length() {
    // I4 quantitative: a lone admission of length L gets
    // min(n_layers, ceil(L / target)) groups.
    check("layered G(L) sizing", 60, |g| {
        let model = ModelDesc::qwen3_30b_a3b();
        let n_layers = model.n_layers;
        let mut cfg = SchedulerConfig::preset(Policy::Layered);
        cfg.group_token_target = *g.pick(&[128u32, 512, 1024]);
        cfg.merge_small_prefills = false;
        let mut state = EngineState::new(model, KvCacheManager::new(200_000, 16), 64);
        let mut policy = sched::build(&cfg, n_layers);
        let len = g.usize(1, 40_000) as u32;
        state.arrive(Request {
            id: 0,
            arrival_s: 0.0,
            input_len: len,
            output_len: 1,
            ..Default::default()
        });
        let plan = policy.plan(&mut state).unwrap();
        let expect = sched::groups_for_len(len, cfg.group_token_target).min(n_layers);
        prop_assert_eq!(plan.groups.len() as u32, expect);
        Ok(())
    });
}
