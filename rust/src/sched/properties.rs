//! Property tests for the normative scheduler invariants I1–I4 (see the
//! module comment in `sched/mod.rs`), checked at the PLAN level over random
//! traces × all five policies with emulated engine effects:
//!
//!  I1  at most one group performs prefill per iteration;
//!  I2  a prompt token visits each layer's prefill path exactly once
//!      (token·layer conservation: exactly input_len × n_layers at
//!      completion, never more along the way);
//!  I3  every running decode request decodes exactly once per iteration
//!      (scheduled in every plan, in groups tiling the full layer stack);
//!  I4  a layer-axis admission (layered cohort / hybrid chunk) completes in
//!      exactly G consecutive iterations, where G is its group count.
//!
//! The emulation mirrors `engine::EngineCore::advance` so the plans are
//! driven exactly as the engine core drives them.
//!
//! Coverage spans BOTH scheduler surfaces: legacy direct constructions,
//! their canonical Policy-API-v2 compositions, random novel pipeline
//! compositions (any admission × shaper × composer), and the adaptive
//! policy — I1–I4 are invariants of the pipeline contracts, not of the
//! five presets.

use std::collections::BTreeMap;

use crate::config::{ModelDesc, Policy, SchedulerConfig};
use crate::kvcache::KvCacheManager;
use crate::sched::policy::{
    AdaptiveSpec, AdmissionSpec, ComposerSpec, FairnessSpec, PolicySpec, PreemptionSpec,
    ShaperSpec,
};
use crate::sched::{self, EngineState, Phase};
use crate::util::proptest::{check, Gen, PropResult};
use crate::workload::Request;
use crate::{prop_assert, prop_assert_eq};

const POLICIES: [Policy; 5] = [
    Policy::Static,
    Policy::Orca,
    Policy::Chunked,
    Policy::Layered,
    Policy::Hybrid,
];

/// Random request set with arrival "times" expressed in iteration indices
/// (plan-level tests have no clock; staggering exercises mid-run admission).
fn random_requests(g: &mut Gen) -> Vec<(u64, Request, usize)> {
    let n = g.usize(1, 8);
    (0..n as u64)
        .map(|id| {
            let r = Request {
                id,
                arrival_s: 0.0,
                // Degenerate inputs included: empty prompts (input_len 0)
                // must drain under every policy (zero-token completing
                // slices), not strand in Prefilling.
                input_len: g.usize(0, 16_000) as u32,
                output_len: g.usize(1, 12) as u32,
                // Priority classes (inert without a preemption stage): mixed
                // classes exercise pause/resume under preempting pipelines.
                priority: g.usize(0, 2) as u8,
                ..Default::default()
            };
            (id, r, g.usize(0, 25))
        })
        .collect()
}

/// A random novel pipeline: any admission × any shaper × any composer.
/// Every combination is strand-free by construction (token-axis shapers
/// sweep the whole prefilling set; the solo shaper sweeps zero-remaining
/// leftovers), so I1–I4 must hold for all of them.
fn random_pipeline(g: &mut Gen) -> PolicySpec {
    let admission = match g.usize(0, 5) {
        0 => AdmissionSpec::Fcfs { max_batch: 64 },
        1 => AdmissionSpec::Batch {
            batch_size: g.usize(1, 8),
        },
        2 => AdmissionSpec::Cohort {
            max_batch: 64,
            merge: g.bool(),
            merge_target: 512,
        },
        3 => AdmissionSpec::Srpf { max_batch: 64 },
        4 => AdmissionSpec::Srpt { max_batch: 64 },
        _ => AdmissionSpec::Solo { max_batch: 64 },
    };
    let shaper = match g.usize(0, 3) {
        0 => ShaperSpec::TokenChunks {
            chunk: *g.pick(&[128u32, 512, 1024]),
        },
        1 => ShaperSpec::FullPrompt,
        2 => ShaperSpec::CohortUnit,
        _ => ShaperSpec::SoloChunk {
            chunk: *g.pick(&[1024u32, 4096]),
        },
    };
    let composer = if g.bool() {
        ComposerSpec::Interleave
    } else {
        ComposerSpec::LayerGroups {
            target: *g.pick(&[128u32, 512]),
        }
    };
    // Preemption composes over any admission: pause/resume must preserve
    // I1–I4 and conservation for every stage combination.
    let preemption = if g.bool() {
        PreemptionSpec::Pause {
            max_pauses: g.usize(1, 4) as u32,
        }
    } else {
        PreemptionSpec::None
    };
    PolicySpec::Pipeline {
        name: None,
        admission,
        shaper,
        composer,
        fairness: FairnessSpec::None,
        preemption,
    }
}

fn random_config(g: &mut Gen) -> SchedulerConfig {
    let policy = *g.pick(&POLICIES);
    let mut cfg = SchedulerConfig::preset(policy);
    cfg.chunk_size = *g.pick(&[128u32, 512, 1024]);
    cfg.group_token_target = *g.pick(&[128u32, 512]);
    cfg.hybrid_chunk_size = *g.pick(&[1024u32, 4096]);
    cfg.static_batch = g.usize(1, 8);
    // Both scheduler surfaces: legacy direct construction, the same
    // config's canonical pipeline composition, a random novel pipeline,
    // or the adaptive policy.
    match g.usize(0, 3) {
        0 => {}
        1 => cfg.spec = Some(PolicySpec::from_config(&cfg)),
        2 => cfg.spec = Some(random_pipeline(g)),
        _ => {
            cfg.spec = Some(PolicySpec::Adaptive(AdaptiveSpec {
                max_batch: 64,
                chunk: *g.pick(&[128u32, 512]),
                group_target: *g.pick(&[128u32, 512]),
                long_prompt: *g.pick(&[256u32, 1024, 4096]),
                window_s: 5.0,
                ..AdaptiveSpec::default()
            }));
        }
    }
    cfg
}

/// Drive one random (trace, policy) pair to drain, checking I1–I4 on every
/// plan and conservation at the end.
fn drive(g: &mut Gen) -> PropResult {
    let model = ModelDesc::qwen3_30b_a3b();
    let n_layers = model.n_layers;
    let cfg = random_config(g);
    let mut state = EngineState::new(model, KvCacheManager::new(200_000, 16), 64);
    let mut policy = sched::build(&cfg, n_layers);
    let mut arrivals = random_requests(g);

    // I4 streak tracking: (prefill ids, pos of first slice) -> group count
    // of those plans and iterations seen so far.
    let mut streak: Option<((Vec<u64>, u32), u32, u32)> = None;
    let mut iter = 0usize;
    loop {
        // Deliver arrivals scheduled for this iteration index.
        arrivals.retain(|(_, r, due)| {
            if *due <= iter {
                state.arrive(*r);
                false
            } else {
                true
            }
        });

        let Some(plan) = policy.plan(&mut state) else {
            if arrivals.is_empty() {
                break;
            }
            iter += 1; // idle until the next staggered arrival
            prop_assert!(iter < 5000, "idle livelock");
            continue;
        };
        iter += 1;
        prop_assert!(iter < 5000, "scheduler did not drain");

        // I1: at most one group prefills.
        prop_assert!(
            plan.prefill_groups() <= 1,
            "I1: {} prefill groups ({})",
            plan.prefill_groups(),
            policy.name()
        );
        // Groups tile the full layer stack.
        prop_assert_eq!(plan.total_layers(), n_layers);

        // I3: every group carries the identical decode set, so each decoding
        // request traverses exactly n_layers; and nobody is left out.
        let first_set: Vec<u64> = plan.groups[0].decode.iter().map(|&(id, _)| id).collect();
        for gr in &plan.groups {
            let set: Vec<u64> = gr.decode.iter().map(|&(id, _)| id).collect();
            prop_assert_eq!(&set, &first_set);
        }
        for id in &state.decoding {
            prop_assert!(
                first_set.contains(id),
                "I3: decoding req {id} unscheduled ({})",
                policy.name()
            );
        }

        // I4: a layer-axis prefill streak — same (ids, pos) across
        // consecutive plans — lasts exactly as many iterations as the plan
        // has groups. Token-axis policies emit single-group plans, so every
        // streak is trivially 1-of-1.
        let prefill_ids: Vec<u64> = plan
            .groups
            .iter()
            .flat_map(|gr| gr.prefill.iter().map(|w| w.req))
            .collect();
        let completes = plan
            .groups
            .iter()
            .any(|gr| gr.prefill.iter().any(|w| w.completes));
        if prefill_ids.is_empty() {
            prop_assert!(streak.is_none(), "I4: streak interrupted by idle plan");
        } else {
            let pos0 = plan
                .groups
                .iter()
                .find_map(|gr| gr.prefill.first())
                .map(|w| w.pos)
                .unwrap();
            let key = (prefill_ids, pos0);
            let g_expected = plan.groups.len() as u32;
            match &mut streak {
                Some((k, exp, seen)) if *k == key => {
                    prop_assert_eq!(*exp, g_expected);
                    *seen += 1;
                }
                Some(_) => {
                    // A new slice may only start after the previous streak
                    // wrapped its groups (cleared below) — changing slices
                    // mid-streak abandons prefill work.
                    return Err("I4: prefill streak changed before completing".into());
                }
                None => streak = Some((key, g_expected, 1)),
            }
            let (_, exp, seen) = streak.as_ref().unwrap();
            prop_assert!(seen <= exp, "I4: streak of {seen} exceeds G={exp}");
            if completes {
                // Prompt done: the slice must have taken exactly G plans.
                prop_assert_eq!(*seen, *exp);
            }
            if seen == exp {
                // Streak wrapped its group cursor (chunked/orca/static wrap
                // every iteration, G = 1); the next slice starts fresh.
                streak = None;
            }
        }

        // ---- emulate engine effects (mirrors EngineCore::advance) ----
        let mut per_req: BTreeMap<u64, (u32, u32, bool)> = BTreeMap::new();
        for gr in &plan.groups {
            for w in &gr.prefill {
                let e = per_req.entry(w.req).or_insert((w.tokens, 0, false));
                e.1 += gr.n_layers;
                e.2 |= w.completes;
            }
        }
        let mut done_prefills = Vec::new();
        for (id, (tokens, layer_sum, w_completes)) in per_req {
            let r = state.reqs.get_mut(&id).unwrap();
            r.token_layers_done += tokens as u64 * layer_sum as u64;
            // I2: never exceed input_len × n_layers.
            prop_assert!(
                r.token_layers_done <= r.req.input_len as u64 * n_layers as u64,
                "I2: req {id} over-prefilled ({})",
                policy.name()
            );
            if w_completes {
                // I2: exactly input_len × n_layers at completion.
                prop_assert_eq!(
                    r.token_layers_done,
                    r.req.input_len as u64 * n_layers as u64
                );
                r.prefill_done = r.req.input_len;
                done_prefills.push(id);
            } else {
                r.prefill_done = (r.token_layers_done / n_layers as u64) as u32;
            }
        }
        for id in done_prefills {
            let r = state.reqs.get_mut(&id).unwrap();
            r.generated = 1;
            state.prefilling.retain(|&x| x != id);
            if r.done_decoding() {
                r.phase = Phase::Finished;
                let _ = state.kv.release(id);
            } else {
                r.phase = Phase::Decoding;
                state.decoding.push(id);
            }
        }
        // Exactly the plan's decode set emits tokens (I3: that set is every
        // request that was decoding at plan time).
        for id in first_set {
            let r = state.reqs.get_mut(&id).unwrap();
            if r.done_decoding() {
                continue;
            }
            r.generated += 1;
            if r.done_decoding() {
                r.phase = Phase::Finished;
                state.decoding.retain(|&x| x != id);
                let _ = state.kv.release(id);
            }
        }
    }

    // Conservation at drain: every request finished with exactly its
    // output budget and a fully-prefilled prompt.
    for (id, r) in state.reqs.iter() {
        prop_assert!(
            r.phase == Phase::Finished,
            "req {id} not finished ({})",
            policy.name()
        );
        prop_assert_eq!(r.generated, r.req.output_len.max(1));
        prop_assert_eq!(r.prefill_done, r.req.input_len);
        prop_assert_eq!(
            r.token_layers_done,
            r.req.input_len as u64 * n_layers as u64
        );
    }
    Ok(())
}

#[test]
fn prop_invariants_i1_i4_all_policies() {
    check("sched invariants I1-I4 x all policies", 60, drive);
}

#[test]
fn prop_layered_cohort_group_counts_match_prompt_length() {
    // I4 quantitative: a lone admission of length L gets
    // min(n_layers, ceil(L / target)) groups.
    check("layered G(L) sizing", 60, |g| {
        let model = ModelDesc::qwen3_30b_a3b();
        let n_layers = model.n_layers;
        let mut cfg = SchedulerConfig::preset(Policy::Layered);
        cfg.group_token_target = *g.pick(&[128u32, 512, 1024]);
        cfg.merge_small_prefills = false;
        let mut state = EngineState::new(model, KvCacheManager::new(200_000, 16), 64);
        let mut policy = sched::build(&cfg, n_layers);
        let len = g.usize(1, 40_000) as u32;
        state.arrive(Request {
            id: 0,
            arrival_s: 0.0,
            input_len: len,
            output_len: 1,
            ..Default::default()
        });
        let plan = policy.plan(&mut state).unwrap();
        let expect = sched::groups_for_len(len, cfg.group_token_target).min(n_layers);
        prop_assert_eq!(plan.groups.len() as u32, expect);
        Ok(())
    });
}
