//! Layered prefill — the paper's contribution (§4).
//!
//! The model is vertically partitioned into G contiguous layer groups
//! (G = max(1, ceil(L / 512)), adapted to the admitted prompt length,
//! §4.4). Each iteration, exactly ONE designated group co-schedules the
//! prefill of the admitted cohort with the ongoing decode batch; every other
//! group runs decode-only. The prefill cursor advances one group per
//! iteration, so an admission completes in exactly G iterations (I4) while
//! decode never stalls (I3). Each prompt token traverses each layer's
//! prefill path exactly once (I2) — eliminating the chunk-amplified MoE
//! expert reloads of token-axis scheduling.
//!
//! Concurrently-arrived small prompts are merged into a single admission
//! cohort (§4.4).
//!
//! Canonical pipeline composition (Policy API v2, bit-identical):
//! `admission=cohort:512, shaper=cohort, composer=groups:512` — see
//! [`crate::sched::policy`].

use crate::config::SchedulerConfig;
use crate::sched::{
    groups_for_len, partition_layers, EngineState, GroupPlan, IterationPlan, PrefillWork,
    Scheduler,
};

pub struct LayeredPrefill {
    cfg: SchedulerConfig,
    n_layers: u32,
    /// Active admission cohort, empty when none in flight. Each member's
    /// prefill slice (tokens, start position) is captured at admission —
    /// `tokens` is the REMAINING prefill, which is less than the full
    /// prompt when the prefix cache credited a cached prefix.
    cohort: Vec<CohortMember>,
    /// Contiguous layer-group sizes for the active cohort.
    group_sizes: Vec<u32>,
    /// Next group to run prefill (0-based). cohort complete when
    /// cursor == group_sizes.len().
    cursor: usize,
}

/// One admitted request's slice within the active cohort.
#[derive(Clone, Copy, Debug)]
struct CohortMember {
    id: u64,
    /// Remaining prompt tokens at admission (post prefix-cache credit).
    tokens: u32,
    /// First token position of the slice (== the cached-prefix credit).
    pos: u32,
}

impl LayeredPrefill {
    pub fn new(cfg: SchedulerConfig, n_layers: u32) -> Self {
        LayeredPrefill {
            cfg,
            n_layers,
            cohort: Vec::new(),
            group_sizes: Vec::new(),
            cursor: 0,
        }
    }

    pub fn active_groups(&self) -> usize {
        self.group_sizes.len()
    }

    fn cohort_active(&self) -> bool {
        !self.cohort.is_empty() && self.cursor < self.group_sizes.len()
    }

    /// Admit the next cohort: FCFS head, merging further waiting requests
    /// while the combined prompt stays within the per-iteration work target
    /// (so merged admissions still cost about one 512-token chunk per
    /// iteration) and capacity allows. The group count G is sized from the
    /// cohort's REMAINING prefill after prefix-cache credit, so a cohort of
    /// warm-prefix prompts completes in fewer iterations.
    fn admit_cohort(&mut self, state: &mut EngineState) {
        debug_assert!(!self.cohort_active());
        self.cohort.clear();
        // Merge budget is judged on declared prompt lengths (pre-credit):
        // conservative and independent of cache temperature, so the cohort
        // shape stays deterministic.
        let mut merged_declared: u32 = 0;
        let mut total_remaining: u32 = 0;
        loop {
            let Some(&head) = state.waiting.first() else {
                break;
            };
            let active = state.prefilling.len() + state.decoding.len();
            if active >= state.max_batch.min(self.cfg.max_batch) {
                break;
            }
            let head_len = state.reqs[&head].req.input_len;
            if !self.cohort.is_empty() {
                if !self.cfg.merge_small_prefills {
                    break;
                }
                // Merge only while the cohort stays "small" (one group's
                // worth of work per §4.4's merged-batch rule).
                if merged_declared.saturating_add(head_len) > self.cfg.group_token_target {
                    break;
                }
            }
            if !state.admit(head) {
                break;
            }
            let r = &state.reqs[&head];
            let member = CohortMember {
                id: head,
                tokens: r.remaining_prefill(),
                pos: r.prefill_done,
            };
            merged_declared = merged_declared.saturating_add(head_len);
            total_remaining = total_remaining.saturating_add(member.tokens);
            self.cohort.push(member);
        }
        if !self.cohort.is_empty() {
            // groups_for_len(0) = 0 for an all-cached / empty-prompt cohort;
            // partition_layers clamps that to one full-stack group so the
            // zero-work admission still completes through an iteration.
            let g = groups_for_len(total_remaining, self.cfg.group_token_target)
                .min(self.n_layers);
            self.group_sizes = partition_layers(self.n_layers, g);
            self.cursor = 0;
        }
    }
}

impl Scheduler for LayeredPrefill {
    fn name(&self) -> &str {
        "layered"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        if !self.cohort_active() {
            self.cohort.clear();
            self.group_sizes.clear();
            self.admit_cohort(state);
        }

        let decode = state.decode_set();
        if !self.cohort_active() && decode.is_empty() {
            return None;
        }

        let mut groups = Vec::new();
        if self.cohort_active() {
            let last = self.cursor == self.group_sizes.len() - 1;
            for (gi, &gsize) in self.group_sizes.iter().enumerate() {
                let prefill = if gi == self.cursor {
                    // One-group-per-iteration rule (I1): the designated group
                    // prefills the cohort's remaining slice through its
                    // layers (the full prompt when no prefix was cached).
                    self.cohort
                        .iter()
                        .map(|m| PrefillWork {
                            req: m.id,
                            tokens: m.tokens,
                            pos: m.pos,
                            completes: last,
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                groups.push(GroupPlan {
                    n_layers: gsize,
                    prefill,
                    decode: decode.clone(),
                });
            }
            self.cursor += 1;
            if last {
                // Cohort completes this iteration; next plan() admits anew.
                self.cohort.clear();
                self.group_sizes.clear();
                self.cursor = 0;
            }
        } else {
            // Decode-only iteration: a single full-stack group.
            groups.push(GroupPlan {
                n_layers: self.n_layers,
                prefill: Vec::new(),
                decode,
            });
        }

        Some(IterationPlan { groups })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn setup() -> (LayeredPrefill, EngineState) {
        let cfg = SchedulerConfig::preset(Policy::Layered);
        let model = ModelDesc::qwen3_30b_a3b();
        let n_layers = model.n_layers;
        let state = EngineState::new(model, KvCacheManager::new(100_000, 16), 256);
        (LayeredPrefill::new(cfg, n_layers), state)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    #[test]
    fn one_group_prefills_per_iteration() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 8192, 10));
        // G = ceil(8192/512) = 16 (paper example).
        for it in 0..16 {
            let p = s.plan(&mut st).unwrap();
            assert_eq!(p.prefill_groups(), 1, "iter {it}");
            assert_eq!(p.groups.len(), 16);
            assert_eq!(p.total_layers(), 48);
            let prefill_group = p.groups.iter().position(|g| !g.prefill.is_empty());
            assert_eq!(prefill_group, Some(it), "cursor advances one group/iter");
            let completes = p.groups[it].prefill[0].completes;
            assert_eq!(completes, it == 15, "completes only on last group (I4)");
        }
    }

    #[test]
    fn prefill_covers_each_layer_exactly_once() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 4000, 10));
        let mut layer_visits = 0u32;
        loop {
            let Some(p) = s.plan(&mut st) else { break };
            let mut done = false;
            for g in &p.groups {
                if !g.prefill.is_empty() {
                    layer_visits += g.n_layers;
                    done = g.prefill[0].completes;
                }
            }
            if done {
                break;
            }
        }
        assert_eq!(layer_visits, 48, "I2: each layer prefilled exactly once");
    }

    #[test]
    fn short_prompt_single_group() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 300, 10));
        let p = s.plan(&mut st).unwrap();
        // G = 1: whole stack in one group, prefill completes immediately.
        assert_eq!(p.groups.len(), 1);
        assert!(p.groups[0].prefill[0].completes);
    }

    #[test]
    fn merges_small_concurrent_prompts() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 100, 5));
        st.arrive(req(2, 150, 5));
        st.arrive(req(3, 200, 5));
        st.arrive(req(4, 400, 5)); // would exceed 512 merged target
        let p = s.plan(&mut st).unwrap();
        let pf: Vec<u64> = p.groups[0].prefill.iter().map(|w| w.req).collect();
        assert_eq!(pf, vec![1, 2, 3], "merged cohort = small prompts only");
        assert_eq!(st.waiting, vec![4]);
    }

    #[test]
    fn decode_present_in_every_group() {
        let (mut s, mut st) = setup();
        // Set up one decoding request.
        st.arrive(req(9, 10, 50));
        st.admit(9);
        {
            let r = st.reqs.get_mut(&9).unwrap();
            r.prefill_done = 10;
            r.generated = 1;
            r.phase = crate::sched::Phase::Decoding;
        }
        st.prefilling.clear();
        st.decoding.push(9);
        // And one long prefill.
        st.arrive(req(1, 2048, 10));
        let p = s.plan(&mut st).unwrap();
        assert!(p.groups.len() > 1);
        for g in &p.groups {
            assert_eq!(g.decode.len(), 1, "I3: decode in every group");
            assert_eq!(g.decode[0].0, 9);
        }
    }

    #[test]
    fn next_cohort_waits_for_current() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 2048, 10)); // G = 4
        let _ = s.plan(&mut st).unwrap();
        st.arrive(req(2, 1000, 10));
        // Request 2 must not enter prefill until request 1's cohort is done.
        for _ in 0..3 {
            let p = s.plan(&mut st).unwrap();
            let ids: Vec<u64> = p
                .groups
                .iter()
                .flat_map(|g| g.prefill.iter().map(|w| w.req))
                .collect();
            assert_eq!(ids, vec![1]);
        }
        // Cohort finished; next plan admits request 2.
        let p = s.plan(&mut st).unwrap();
        let ids: Vec<u64> = p
            .groups
            .iter()
            .flat_map(|g| g.prefill.iter().map(|w| w.req))
            .collect();
        assert_eq!(ids, vec![2]);
    }

    #[test]
    fn prefix_credit_shrinks_cohort_groups_and_slices() {
        let (mut s, mut st) = setup();
        st.kv.enable_prefix_cache();
        let mk = |id: u64| Request {
            id,
            input_len: 2048,
            output_len: 10,
            prefix_id: 5,
            prefix_len: 1600, // 100 shared blocks of 16
            ..Default::default()
        };
        // Cold: full 2048-token slice, G = 4.
        st.arrive(mk(1));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups.len(), 4);
        let w = p.groups.iter().find_map(|g| g.prefill.first()).unwrap();
        assert_eq!((w.tokens, w.pos), (2048, 0));
        // Drain the cohort (3 more iterations).
        for _ in 0..3 {
            let _ = s.plan(&mut st).unwrap();
        }
        // Emulate the engine observing request 1's prefill completion
        // (publication is deferred until the content exists).
        let hashes =
            crate::kvcache::shared_block_hashes(&st.reqs[&1].req, st.kv.block_size);
        assert!(st.kv.publish_prefix(1, &hashes) > 0);
        // Warm: the 1600 shared tokens are credited; the slice is the
        // 448-token remainder starting at 1600, and G shrinks to 1.
        st.arrive(mk(2));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups.len(), 1);
        let w = p.groups.iter().find_map(|g| g.prefill.first()).unwrap();
        assert_eq!((w.tokens, w.pos), (448, 1600));
        assert!(w.completes);
    }

    #[test]
    fn zero_length_prompt_completes_in_one_iteration() {
        let (mut s, mut st) = setup();
        st.arrive(req(1, 0, 3));
        let p = s.plan(&mut st).unwrap();
        // G(0) = 0 clamps to a single full-stack group carrying the
        // completing zero-token slice.
        assert_eq!(p.groups.len(), 1);
        let w = p.groups[0].prefill[0];
        assert_eq!((w.tokens, w.pos), (0, 0));
        assert!(w.completes);
    }

    #[test]
    fn groups_capped_by_layer_count() {
        let cfg = SchedulerConfig::preset(Policy::Layered);
        let model = ModelDesc::tinymoe(); // 8 layers
        let mut st = EngineState::new(model, KvCacheManager::new(100_000, 16), 256);
        let mut s = LayeredPrefill::new(cfg, 8);
        st.arrive(req(1, 30_000, 10)); // ceil(30000/512) = 59 > 8 layers
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups.len(), 8, "G clamped to n_layers");
    }
}
