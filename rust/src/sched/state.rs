//! Engine-side request state shared between the simulator and the
//! schedulers. The engine owns canonical progress; schedulers read it and
//! perform admissions (waiting -> prefilling) against the KV manager.

use std::collections::BTreeMap;

use crate::config::ModelDesc;
use crate::kvcache::KvCacheManager;
use crate::workload::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Waiting,
    Prefilling,
    Decoding,
    Finished,
}

/// Mutable per-request progress tracked by the engine.
#[derive(Clone, Debug)]
pub struct SimReq {
    pub req: Request,
    pub phase: Phase,
    /// Prompt tokens fully prefilled **through all layers** (chunked /
    /// token-axis progress).
    pub prefill_done: u32,
    /// Prefill token·layer units processed (I2 accounting: equals
    /// input_len × n_layers exactly when prefill completes).
    pub token_layers_done: u64,
    /// Tokens generated so far (including the first token from prefill).
    pub generated: u32,
    /// Timestamps for metrics.
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    /// Inter-token gaps (decode TBTs).
    pub tbts: Vec<f64>,
    pub token_times: Vec<f64>,
}

impl SimReq {
    pub fn new(req: Request) -> Self {
        SimReq {
            req,
            phase: Phase::Waiting,
            prefill_done: 0,
            token_layers_done: 0,
            generated: 0,
            first_token_s: None,
            finish_s: None,
            tbts: Vec::new(),
            token_times: Vec::new(),
        }
    }

    pub fn remaining_prefill(&self) -> u32 {
        self.req.input_len - self.prefill_done
    }

    pub fn ctx_len(&self) -> u32 {
        // Context visible to the next decode step: full prompt + generated.
        self.req.input_len + self.generated
    }

    pub fn done_decoding(&self) -> bool {
        self.generated >= self.req.output_len
    }
}

/// Outcome of one admission attempt, recorded by [`EngineState::admit`]
/// for the engine core to translate into the typed event stream
/// ([`EngineEvent`](crate::serve::EngineEvent)). The sched layer stays
/// independent of the serve layer by logging this minimal form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// KV reserved; the request entered prefilling.
    Admitted { id: u64 },
    /// KV capacity refused the request's footprint (`demand` blocks needed,
    /// `free` available) — the admission backpressure signal.
    KvRejected { id: u64, demand: u32, free: u32 },
}

/// Engine state visible to schedulers.
pub struct EngineState {
    pub model: ModelDesc,
    pub now_s: f64,
    /// Arrived but not admitted (FCFS order).
    pub waiting: Vec<u64>,
    /// Admitted, prefill in progress.
    pub prefilling: Vec<u64>,
    /// Prefill complete, generating.
    pub decoding: Vec<u64>,
    pub reqs: BTreeMap<u64, SimReq>,
    pub kv: KvCacheManager,
    /// Scheduler-visible cap on concurrent decodes.
    pub max_batch: usize,
    /// Admission outcomes since the engine core last drained this log
    /// (every `EngineState::admit` call appends one entry).
    pub admissions: Vec<Admission>,
}

impl EngineState {
    pub fn new(model: ModelDesc, kv: KvCacheManager, max_batch: usize) -> Self {
        EngineState {
            model,
            now_s: 0.0,
            waiting: Vec::new(),
            prefilling: Vec::new(),
            decoding: Vec::new(),
            reqs: BTreeMap::new(),
            kv,
            max_batch,
            admissions: Vec::new(),
        }
    }

    pub fn arrive(&mut self, req: Request) {
        let id = req.id;
        self.reqs.insert(id, SimReq::new(req));
        self.waiting.push(id);
    }

    /// Admit a waiting request (FCFS position `idx` in waiting) into
    /// prefilling, reserving KV for its full footprint. Returns false if KV
    /// capacity does not allow admission.
    pub fn admit(&mut self, id: u64) -> bool {
        let Some(pos) = self.waiting.iter().position(|&w| w == id) else {
            return false;
        };
        let footprint = {
            let r = &self.reqs[&id];
            r.req.input_len + r.req.output_len
        };
        if !self.kv.can_admit(footprint) {
            self.admissions.push(Admission::KvRejected {
                id,
                demand: self.kv.blocks_for(footprint),
                free: self.kv.free_blocks(),
            });
            return false;
        }
        self.kv.register(id, footprint).expect("can_admit checked");
        self.waiting.remove(pos);
        self.prefilling.push(id);
        self.reqs.get_mut(&id).unwrap().phase = Phase::Prefilling;
        self.admissions.push(Admission::Admitted { id });
        true
    }

    /// Pull a WAITING request back out (it holds no KV reservation yet) and
    /// return its original [`Request`]. The serving session uses this to
    /// requeue a KV-rejected arrival onto another replica (adaptive spill).
    /// Returns `None` if `id` is not currently waiting — e.g. it was
    /// admitted between the rejection and the requeue attempt, in which
    /// case it must stay where its KV lives.
    pub fn requeue_waiting(&mut self, id: u64) -> Option<Request> {
        let pos = self.waiting.iter().position(|&w| w == id)?;
        self.waiting.remove(pos);
        let sim = self.reqs.remove(&id)?;
        Some(sim.req)
    }

    /// Remove EVERY waiting (not yet admitted) request, in FCFS order — the
    /// graceful-drain handoff: the fleet re-routes them while requests
    /// already admitted here run to completion. Safe under any scheduler:
    /// policies re-read `waiting` fresh each plan and hold internal state
    /// only for admitted requests.
    pub fn take_waiting(&mut self) -> Vec<Request> {
        let ids = std::mem::take(&mut self.waiting);
        ids.into_iter()
            .filter_map(|id| self.reqs.remove(&id).map(|s| s.req))
            .collect()
    }

    /// Evict EVERY unfinished request — waiting, prefilling, decoding —
    /// releasing their KV and DISCARDING their progress (replica failure:
    /// the fleet re-serves them from scratch; tokens the dead replica had
    /// already streamed are discarded, the retry model production failover
    /// uses). Finished requests keep their records. Callers must also
    /// rebuild the replica's scheduler: policies hold planning state for
    /// admitted requests (layered cohorts, hybrid chunks) that this wipes.
    pub fn evict_unfinished(&mut self) -> Vec<Request> {
        let mut out = self.take_waiting();
        let in_flight = std::mem::take(&mut self.prefilling)
            .into_iter()
            .chain(std::mem::take(&mut self.decoding));
        for id in in_flight {
            let _ = self.kv.release(id);
            if let Some(s) = self.reqs.remove(&id) {
                out.push(s.req);
            }
        }
        out
    }

    /// Total decode slots in use (prefilling requests don't decode yet).
    pub fn decode_batch_size(&self) -> usize {
        self.decoding.len()
    }

    pub fn ctx_lens_of(&self, ids: &[u64]) -> Vec<u64> {
        ids.iter()
            .map(|id| self.reqs[id].ctx_len() as u64)
            .collect()
    }

    /// Decode set for planning: every decoding request (I3: all decode every
    /// iteration), as (id, ctx_len) pairs.
    pub fn decode_set(&self) -> Vec<(u64, u32)> {
        self.decoding
            .iter()
            .map(|id| (*id, self.reqs[id].ctx_len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
        }
    }

    #[test]
    fn arrive_admit_flow() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert_eq!(s.waiting, vec![1]);
        assert!(s.admit(1));
        assert_eq!(s.waiting.len(), 0);
        assert_eq!(s.prefilling, vec![1]);
        assert_eq!(s.reqs[&1].phase, Phase::Prefilling);
        // KV reserved for input+output
        assert_eq!(s.kv.len_of(1), Some(110));
    }

    #[test]
    fn admit_blocked_by_kv() {
        let mut s = state();
        s.arrive(req(1, 100 * 16, 500 * 16)); // way beyond 100 blocks
        assert!(!s.admit(1));
        assert_eq!(s.waiting, vec![1]);
    }

    #[test]
    fn admissions_are_logged() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        s.arrive(req(2, 100 * 16, 500 * 16)); // beyond 100 blocks
        assert!(s.admit(1));
        assert!(!s.admit(2));
        assert_eq!(s.admissions.len(), 2);
        assert_eq!(s.admissions[0], Admission::Admitted { id: 1 });
        match s.admissions[1] {
            Admission::KvRejected { id, demand, free } => {
                assert_eq!(id, 2);
                assert!(demand > free);
            }
            _ => panic!("expected KvRejected"),
        }
    }

    #[test]
    fn requeue_and_eviction_helpers() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        s.arrive(req(2, 200, 10));
        s.arrive(req(3, 300, 10));
        assert!(s.admit(1));
        // Requeue a waiting request: removed entirely, returned intact.
        let r2 = s.requeue_waiting(2).unwrap();
        assert_eq!((r2.id, r2.input_len), (2, 200));
        assert!(s.requeue_waiting(2).is_none());
        assert!(s.requeue_waiting(1).is_none(), "admitted requests stay put");
        assert_eq!(s.waiting, vec![3]);
        // take_waiting empties the queue in FCFS order.
        let rest = s.take_waiting();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
        assert!(s.waiting.is_empty());
        // evict_unfinished clears the admitted request and frees its KV.
        assert_eq!(s.kv.len_of(1), Some(110));
        let evicted = s.evict_unfinished();
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert!(s.prefilling.is_empty());
        assert_eq!(s.kv.len_of(1), None);
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn ctx_len_accounts_generated() {
        let mut r = SimReq::new(req(1, 50, 10));
        assert_eq!(r.ctx_len(), 50);
        r.generated = 3;
        assert_eq!(r.ctx_len(), 53);
        assert_eq!(r.remaining_prefill(), 50);
        r.prefill_done = 20;
        assert_eq!(r.remaining_prefill(), 30);
    }
}
