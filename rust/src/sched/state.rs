//! Engine-side request state shared between the simulator and the
//! schedulers. The engine owns canonical progress; schedulers read it and
//! perform admissions (waiting -> prefilling) against the KV manager.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use crate::config::ModelDesc;
use crate::kvcache::KvCacheManager;
use crate::tenant::{RejectReason, TenantAccounting};
use crate::workload::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Waiting,
    Prefilling,
    /// Admitted prefill paused by a preemption policy: KV stays reserved
    /// and `prefill_done` / `token_layers_done` are preserved; the request
    /// consumes no slice budget until resumed.
    Paused,
    Decoding,
    Finished,
}

/// Mutable per-request progress tracked by the engine.
#[derive(Clone, Debug)]
pub struct SimReq {
    pub req: Request,
    pub phase: Phase,
    /// Prompt tokens fully prefilled **through all layers** (chunked /
    /// token-axis progress).
    pub prefill_done: u32,
    /// Prefill token·layer units processed (I2 accounting: equals
    /// input_len × n_layers exactly when prefill completes).
    pub token_layers_done: u64,
    /// Tokens generated so far (including the first token from prefill).
    pub generated: u32,
    /// Timestamps for metrics.
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    /// Inter-token gaps (decode TBTs).
    pub tbts: Vec<f64>,
    pub token_times: Vec<f64>,
}

impl SimReq {
    pub fn new(req: Request) -> Self {
        SimReq {
            req,
            phase: Phase::Waiting,
            prefill_done: 0,
            token_layers_done: 0,
            generated: 0,
            first_token_s: None,
            finish_s: None,
            tbts: Vec::new(),
            token_times: Vec::new(),
        }
    }

    pub fn remaining_prefill(&self) -> u32 {
        self.req.input_len - self.prefill_done
    }

    pub fn ctx_len(&self) -> u32 {
        // Context visible to the next decode step: full prompt + generated.
        self.req.input_len + self.generated
    }

    pub fn done_decoding(&self) -> bool {
        self.generated >= self.req.output_len
    }
}

/// Outcome of one admission attempt, recorded by [`EngineState::admit`]
/// for the engine core to translate into the typed event stream
/// ([`EngineEvent`](crate::serve::EngineEvent)). The sched layer stays
/// independent of the serve layer by logging this minimal form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// KV reserved; the request entered prefilling (or, for an adopted
    /// migration, decoding). `cached_tokens` is the prompt credit taken
    /// from the prefix cache — that much prefill is skipped (0 when the
    /// prefix cache is off or cold).
    Admitted { id: u64, cached_tokens: u32 },
    /// Admission refused the request — the backpressure signal. For
    /// [`RejectReason::KvCapacity`], `demand` is the blocks needed beyond
    /// any cached-prefix credit and `free` the blocks available; for
    /// tenant-budget refusals (`TenantQuota` / `TenantRate`), `demand` is
    /// the request's gross block footprint and `free` the KV blocks
    /// currently available (the pool was not the constraint).
    KvRejected {
        id: u64,
        demand: u32,
        free: u32,
        reason: RejectReason,
    },
    /// A preemption policy paused an in-flight prefill
    /// ([`EngineState::pause_prefill`]): KV retained, progress preserved
    /// at `token_layers_done` token·layer units.
    Paused { id: u64, token_layers_done: u64 },
    /// A paused prefill re-entered the prefilling set
    /// ([`EngineState::resume_prefill`]).
    Resumed { id: u64 },
}

/// Multiply-shift hasher for request ids — ids are already well-spread
/// integers, so SipHash's per-lookup cost (the default `HashMap` hasher)
/// is pure overhead on the plan/advance hot path.
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        // Fallback for non-u64 keys (never hit by ReqTable).
        for &b in bytes {
            self.0 = self.0.rotate_left(8) ^ b as u64;
        }
    }
    fn write_u64(&mut self, v: u64) {
        self.0 = v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
}

/// Slab-backed request table: the zero-alloc-hot-path replacement for the
/// old `BTreeMap<u64, SimReq>`. Live requests occupy dense slab slots
/// (freed slots are recycled LIFO, so a steady-state run stops allocating
/// entirely); an id → slot index keeps the map-like API — `insert` /
/// `remove` / `get` / `get_mut` / `contains_key` / `Index<&u64>` — that
/// the schedulers and engine core already use.
///
/// Iteration order is SLOT order (insertion order modulo slot reuse), not
/// ascending id like the BTreeMap was; the only iterating caller (a
/// drain-time conservation check) is order-independent. Hot-path readers
/// never iterate — they index by id.
#[derive(Default)]
pub struct ReqTable {
    slots: Vec<Option<SimReq>>,
    free: Vec<u32>,
    index: HashMap<u64, u32, BuildHasherDefault<IdHasher>>,
}

impl ReqTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains_key(&self, id: &u64) -> bool {
        self.index.contains_key(id)
    }

    pub fn get(&self, id: &u64) -> Option<&SimReq> {
        let &slot = self.index.get(id)?;
        self.slots[slot as usize].as_ref()
    }

    pub fn get_mut(&mut self, id: &u64) -> Option<&mut SimReq> {
        let &slot = self.index.get(id)?;
        self.slots[slot as usize].as_mut()
    }

    /// Insert `sim` under `id`, returning the previous entry if one was
    /// live (same replace semantics as `BTreeMap::insert`).
    pub fn insert(&mut self, id: u64, sim: SimReq) -> Option<SimReq> {
        if let Some(&slot) = self.index.get(&id) {
            return self.slots[slot as usize].replace(sim);
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize] = Some(sim);
                s
            }
            None => {
                self.slots.push(Some(sim));
                (self.slots.len() - 1) as u32
            }
        };
        self.index.insert(id, slot);
        None
    }

    pub fn remove(&mut self, id: &u64) -> Option<SimReq> {
        let slot = self.index.remove(id)?;
        let sim = self.slots[slot as usize].take();
        debug_assert!(sim.is_some(), "index pointed at an empty slot");
        self.free.push(slot);
        sim
    }

    /// Live entries in slot order (NOT id order; see the type docs).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &SimReq)> {
        self.slots
            .iter()
            .filter_map(|s| s.as_ref().map(|r| (r.req.id, r)))
    }
}

impl std::ops::Index<&u64> for ReqTable {
    type Output = SimReq;
    fn index(&self, id: &u64) -> &SimReq {
        self.get(id).expect("no request with this id")
    }
}

/// Engine state visible to schedulers.
pub struct EngineState {
    pub model: ModelDesc,
    pub now_s: f64,
    /// Arrived but not admitted (FCFS order).
    pub waiting: Vec<u64>,
    /// Admitted, prefill in progress.
    pub prefilling: Vec<u64>,
    /// Admitted prefills paused by a preemption policy (KV retained,
    /// progress preserved; see [`EngineState::pause_prefill`]). Always
    /// empty when no preemption policy is active — feature-off paths
    /// never observe it.
    pub paused: Vec<u64>,
    /// Prefill complete, generating.
    pub decoding: Vec<u64>,
    pub reqs: ReqTable,
    pub kv: KvCacheManager,
    /// Scheduler-visible cap on concurrent decodes.
    pub max_batch: usize,
    /// Admission outcomes since the engine core last drained this log
    /// (every `EngineState::admit` call appends one entry).
    pub admissions: Vec<Admission>,
    /// Per-tenant enforcement (quota ledgers + token buckets) for THIS
    /// replica. `None` (the default) disables every tenant check —
    /// admission behaves bit-identically to the pre-tenant engine.
    pub tenants: Option<TenantAccounting>,
}

impl EngineState {
    pub fn new(model: ModelDesc, kv: KvCacheManager, max_batch: usize) -> Self {
        EngineState {
            model,
            now_s: 0.0,
            waiting: Vec::new(),
            prefilling: Vec::new(),
            paused: Vec::new(),
            decoding: Vec::new(),
            reqs: ReqTable::new(),
            kv,
            max_batch,
            admissions: Vec::new(),
            tenants: None,
        }
    }

    pub fn arrive(&mut self, req: Request) {
        let id = req.id;
        self.reqs.insert(id, SimReq::new(req));
        self.waiting.push(id);
    }

    /// Admit a waiting request (FCFS position `idx` in waiting) into
    /// prefilling, reserving KV for its full footprint. Returns false if KV
    /// capacity does not allow admission.
    ///
    /// With the prefix cache enabled, admission first looks the request's
    /// block-aligned prompt hashes up: cached blocks are credited — the
    /// request's `prefill_done` / `token_layers_done` start at the credit,
    /// so EVERY policy's `remaining_prefill` shrinks — and the KV demand
    /// drops by the hit count. Credit is capped one token short of the full
    /// prompt (the last prompt token must be recomputed to produce the
    /// first output logits, the vLLM rule), so prefill always completes
    /// through a planned iteration. A migrated request re-entering via
    /// [`EngineState::adopt_waiting`] keeps its preserved progress instead
    /// (no cache lookup; the blocks moved with it).
    pub fn admit(&mut self, id: u64) -> bool {
        let Some(pos) = self.waiting.iter().position(|&w| w == id) else {
            return false;
        };
        let (footprint, hashes, prior_done, tenant) = {
            let r = &self.reqs[&id];
            let fp = r.req.input_len.saturating_add(r.req.output_len);
            let hashes = if self.kv.prefix_cache_enabled() && r.prefill_done == 0 {
                crate::kvcache::shared_block_hashes(&r.req, self.kv.block_size)
            } else {
                Vec::new()
            };
            (fp, hashes, r.prefill_done, r.req.tenant)
        };
        let gross_blocks = self.kv.blocks_for(footprint);
        // Tenant budgets gate admission BEFORE any KV registration, so a
        // tenant-refused request touches no pool state (peek → register →
        // commit; see `tenant::TenantAccounting`). Peek and commit both
        // use [`EngineState::admission_cost`] — the SAME prefix-credit-
        // aware cost the fair queue's eligibility peek reads — so the
        // sort order and the ledger can never disagree.
        let tenant_cost = if tenant != 0 && self.tenants.is_some() {
            Some(self.admission_cost(id))
        } else {
            None
        };
        if let Some((cost_blocks, cost_tokens)) = tenant_cost {
            let acct = self.tenants.as_ref().unwrap();
            if let Err(reason) = acct.peek(tenant, cost_blocks, cost_tokens, self.now_s) {
                let (_, avail) = self.kv.admission_outlook(footprint, &hashes);
                self.admissions.push(Admission::KvRejected {
                    id,
                    demand: gross_blocks,
                    free: avail,
                    reason,
                });
                return false;
            }
        }
        // Single admission walk: register directly and report on failure
        // (a pre-check would repeat the whole hash/availability scan).
        let cached_blocks = match self.kv.register_with_prefix(id, footprint, &hashes) {
            Ok(hits) => hits,
            Err(_) => {
                let (hits, avail) = self.kv.admission_outlook(footprint, &hashes);
                self.admissions.push(Admission::KvRejected {
                    id,
                    demand: gross_blocks.saturating_sub(hits),
                    free: avail,
                    reason: RejectReason::KvCapacity,
                });
                return false;
            }
        };
        if let Some((cost_blocks, cost_tokens)) = tenant_cost {
            let acct = self.tenants.as_mut().unwrap();
            acct.commit(id, tenant, cost_blocks, cost_tokens, self.now_s);
        }
        let cached_tokens = cached_blocks.saturating_mul(self.kv.block_size);
        self.waiting.remove(pos);
        self.prefilling.push(id);
        let n_layers = self.model.n_layers as u64;
        let r = self.reqs.get_mut(&id).unwrap();
        r.phase = Phase::Prefilling;
        if cached_tokens > 0 && prior_done == 0 {
            // Hashes never cover the final prompt token, so the credit is
            // strictly below input_len and prefill still completes via a
            // planned (possibly tiny) slice.
            r.prefill_done = cached_tokens.min(r.req.input_len.saturating_sub(1));
            r.token_layers_done = r.prefill_done as u64 * n_layers;
        }
        self.admissions.push(Admission::Admitted {
            id,
            cached_tokens: if prior_done == 0 { r.prefill_done } else { 0 },
        });
        true
    }

    /// The prefix-credit-aware admission cost of request `id`, as
    /// `(blocks, prefill_tokens)`: the KV blocks the pool must newly
    /// allocate for its footprint (gross blocks minus expected
    /// prefix-cache hits) and the prompt tokens that will actually be
    /// computed here (declared length minus expected cached credit, or
    /// the preserved remainder for a migrated request). This is the ONE
    /// cost function behind every tenant-budget decision — the admission
    /// gate's peek AND commit ([`EngineState::admit`]), the fair queue's
    /// eligibility peek ([`crate::tenant::FairQueue`]), and the
    /// rate-refusal wake-up scan ([`EngineState::next_tenant_ready`]) —
    /// so a warm-prefix request can never sort as ineligible yet admit
    /// fine, or vice versa. Pure: reads the prefix cache via
    /// [`KvCacheManager::lookup_prefix`], registers nothing.
    pub fn admission_cost(&self, id: u64) -> (u32, u32) {
        let r = &self.reqs[&id];
        let footprint = r.req.input_len.saturating_add(r.req.output_len);
        let gross = self.kv.blocks_for(footprint);
        if self.kv.prefix_cache_enabled() && r.prefill_done == 0 {
            let hashes = crate::kvcache::shared_block_hashes(&r.req, self.kv.block_size);
            let hits = self.kv.lookup_prefix(&hashes);
            // Credit caps one token short of the prompt — the same rule
            // `admit` applies when seeding `prefill_done`.
            let credit = hits
                .saturating_mul(self.kv.block_size)
                .min(r.req.input_len.saturating_sub(1));
            (gross.saturating_sub(hits), r.req.input_len - credit)
        } else {
            // No cache (or preserved migration progress): charge the
            // remaining uncached prefill against the full reservation.
            (gross, r.remaining_prefill())
        }
    }

    /// Pause an in-flight prefill (preemption): the request leaves
    /// `prefilling` — so shapers stop slicing it and its budget frees up
    /// from the next unit on — but keeps its KV reservation, its tenant
    /// charge, and every unit of progress (`prefill_done`,
    /// `token_layers_done`), so nothing is ever recomputed on resume.
    /// Callers must only pause at unit boundaries (inside
    /// [`AdmissionPolicy::admit`](crate::sched::policy::AdmissionPolicy),
    /// which the pipeline invokes only between units), so a layer-axis
    /// unit's G-iteration streak (I4) is never interrupted. No-op unless
    /// the request is currently prefilling with work remaining.
    pub fn pause_prefill(&mut self, id: u64) -> bool {
        let Some(pos) = self.prefilling.iter().position(|&p| p == id) else {
            return false;
        };
        let r = self.reqs.get_mut(&id).unwrap();
        if r.remaining_prefill() == 0 {
            return false;
        }
        r.phase = Phase::Paused;
        let token_layers_done = r.token_layers_done;
        self.prefilling.remove(pos);
        self.paused.push(id);
        self.admissions.push(Admission::Paused {
            id,
            token_layers_done,
        });
        true
    }

    /// Resume a paused prefill: it rejoins `prefilling` (at the back, so
    /// already-running prefills keep their slice order) with its preserved
    /// progress — the next unit slices exactly `remaining_prefill()`
    /// tokens, never recomputing a token·layer unit (I2 conservation).
    pub fn resume_prefill(&mut self, id: u64) -> bool {
        let Some(pos) = self.paused.iter().position(|&p| p == id) else {
            return false;
        };
        self.paused.remove(pos);
        self.prefilling.push(id);
        self.reqs.get_mut(&id).unwrap().phase = Phase::Prefilling;
        self.admissions.push(Admission::Resumed { id });
        true
    }

    /// Release a request's KV reservation AND its tenant block charge in
    /// one step. Every path that frees an admitted request's KV (finish,
    /// migration extraction, failure eviction) must go through here so the
    /// quota ledger never leaks.
    pub fn release_kv(&mut self, id: u64) {
        let _ = self.kv.release(id);
        if let Some(acct) = self.tenants.as_mut() {
            acct.release(id);
        }
    }

    /// Earliest future instant at which some waiting request, refused at
    /// `now_s` purely on its tenant's token bucket, would pass that
    /// bucket. The engine core folds this into its idle target: a drain
    /// whose only remaining work is rate-throttled keeps advancing the
    /// clock (buckets refill on engine time) instead of declaring the
    /// replica drained with work stranded. `None` when tenant enforcement
    /// is off or nothing waiting is purely rate-gated — the feature-off
    /// idle path is untouched.
    pub fn next_tenant_ready(&self) -> Option<f64> {
        let acct = self.tenants.as_ref()?;
        let mut best: Option<f64> = None;
        for &id in &self.waiting {
            let tenant = self.reqs[&id].req.tenant;
            let (blocks, tokens) = self.admission_cost(id);
            if let Some(t) = acct.ready_time(tenant, blocks, tokens, self.now_s) {
                best = Some(best.map_or(t, |b: f64| b.min(t)));
            }
        }
        best
    }

    /// Re-insert a migrated request into the waiting queue WITH its
    /// preserved prefill progress (cross-replica KV migration landing path
    /// for requests still mid-prefill). Admission later re-registers its KV
    /// reservation and keeps the progress, so only `remaining_prefill` is
    /// ever recomputed.
    pub fn adopt_waiting(&mut self, sim: SimReq) {
        let id = sim.req.id;
        debug_assert!(!self.reqs.contains_key(&id), "adopting a live id");
        let mut sim = sim;
        sim.phase = Phase::Waiting;
        self.reqs.insert(id, sim);
        self.waiting.push(id);
    }

    /// Adopt a migrated request whose prefill is already complete directly
    /// into the decode set, reserving KV for its full footprint. Returns
    /// the request back when the pool cannot hold it (caller falls back to
    /// re-serving from scratch — zero loss, progress dropped).
    pub fn adopt_decoding(&mut self, sim: SimReq) -> Result<(), SimReq> {
        let id = sim.req.id;
        let footprint = sim.req.input_len.saturating_add(sim.req.output_len);
        if self.reqs.contains_key(&id) || !self.kv.can_admit(footprint) {
            return Err(sim);
        }
        if self.kv.register(id, footprint).is_err() {
            return Err(sim);
        }
        // Migration preserves already-admitted work: the landing replica
        // charges the tenant ledger but never refuses on tenant budgets
        // (quota transfers may transiently exceed the destination's cap).
        if sim.req.tenant != 0 {
            let blocks = self.kv.blocks_for(footprint);
            if let Some(acct) = self.tenants.as_mut() {
                acct.charge_unchecked(id, sim.req.tenant, blocks);
            }
        }
        let mut sim = sim;
        sim.phase = Phase::Decoding;
        self.reqs.insert(id, sim);
        self.decoding.push(id);
        self.admissions.push(Admission::Admitted {
            id,
            cached_tokens: 0,
        });
        Ok(())
    }

    /// Migration extraction (replica failure/drain with `--migrate-kv`):
    /// remove every ADMITTED unfinished request, releasing its KV locally,
    /// and return the preserved per-request progress plus the block count a
    /// migration must move (`blocks_for(prefill_done + generated)` — the
    /// computed KV, not the whole reservation). Token-axis progress is
    /// rounded DOWN to fully-completed layer stacks so `token_layers_done`
    /// conservation stays exact on the resumed replica (partial layered-
    /// cohort progress is discarded, never double-counted).
    pub fn extract_unfinished(&mut self) -> Vec<(SimReq, u32)> {
        let n_layers = (self.model.n_layers as u64).max(1);
        let in_flight: Vec<u64> = std::mem::take(&mut self.prefilling)
            .into_iter()
            .chain(std::mem::take(&mut self.paused))
            .chain(std::mem::take(&mut self.decoding))
            .collect();
        let mut out = Vec::with_capacity(in_flight.len());
        for id in in_flight {
            self.release_kv(id);
            if let Some(mut s) = self.reqs.remove(&id) {
                s.prefill_done = (s.token_layers_done / n_layers) as u32;
                s.token_layers_done = s.prefill_done as u64 * n_layers;
                let moved = self
                    .kv
                    .blocks_for(s.prefill_done.saturating_add(s.generated));
                out.push((s, moved));
            }
        }
        out
    }

    /// Pull a WAITING request back out (it holds no KV reservation yet) and
    /// return its original [`Request`]. The serving session uses this to
    /// requeue a KV-rejected arrival onto another replica (adaptive spill).
    /// Returns `None` if `id` is not currently waiting — e.g. it was
    /// admitted between the rejection and the requeue attempt, in which
    /// case it must stay where its KV lives.
    pub fn requeue_waiting(&mut self, id: u64) -> Option<Request> {
        let pos = self.waiting.iter().position(|&w| w == id)?;
        self.waiting.remove(pos);
        let sim = self.reqs.remove(&id)?;
        Some(sim.req)
    }

    /// Remove EVERY waiting (not yet admitted) request, in FCFS order — the
    /// graceful-drain handoff: the fleet re-routes them while requests
    /// already admitted here run to completion. Safe under any scheduler:
    /// policies re-read `waiting` fresh each plan and hold internal state
    /// only for admitted requests.
    pub fn take_waiting(&mut self) -> Vec<Request> {
        let ids = std::mem::take(&mut self.waiting);
        ids.into_iter()
            .filter_map(|id| self.reqs.remove(&id).map(|s| s.req))
            .collect()
    }

    /// Evict EVERY unfinished request — waiting, prefilling, decoding —
    /// releasing their KV and DISCARDING their progress (replica failure:
    /// the fleet re-serves them from scratch; tokens the dead replica had
    /// already streamed are discarded, the retry model production failover
    /// uses). Finished requests keep their records. Callers must also
    /// rebuild the replica's scheduler: policies hold planning state for
    /// admitted requests (layered cohorts, hybrid chunks) that this wipes.
    pub fn evict_unfinished(&mut self) -> Vec<Request> {
        let mut out = self.take_waiting();
        let in_flight = std::mem::take(&mut self.prefilling)
            .into_iter()
            .chain(std::mem::take(&mut self.paused))
            .chain(std::mem::take(&mut self.decoding));
        for id in in_flight {
            self.release_kv(id);
            if let Some(s) = self.reqs.remove(&id) {
                out.push(s.req);
            }
        }
        out
    }

    /// Total decode slots in use (prefilling requests don't decode yet).
    pub fn decode_batch_size(&self) -> usize {
        self.decoding.len()
    }

    pub fn ctx_lens_of(&self, ids: &[u64]) -> Vec<u64> {
        ids.iter()
            .map(|id| self.reqs[id].ctx_len() as u64)
            .collect()
    }

    /// Decode set for planning: every decoding request (I3: all decode every
    /// iteration), as (id, ctx_len) pairs.
    pub fn decode_set(&self) -> Vec<(u64, u32)> {
        self.decoding
            .iter()
            .map(|id| (*id, self.reqs[id].ctx_len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> EngineState {
        EngineState::new(
            ModelDesc::qwen3_30b_a3b(),
            KvCacheManager::new(100, 16),
            256,
        )
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            ..Default::default()
        }
    }

    #[test]
    fn arrive_admit_flow() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert_eq!(s.waiting, vec![1]);
        assert!(s.admit(1));
        assert_eq!(s.waiting.len(), 0);
        assert_eq!(s.prefilling, vec![1]);
        assert_eq!(s.reqs[&1].phase, Phase::Prefilling);
        // KV reserved for input+output
        assert_eq!(s.kv.len_of(1), Some(110));
    }

    #[test]
    fn admit_blocked_by_kv() {
        let mut s = state();
        s.arrive(req(1, 100 * 16, 500 * 16)); // way beyond 100 blocks
        assert!(!s.admit(1));
        assert_eq!(s.waiting, vec![1]);
    }

    #[test]
    fn admissions_are_logged() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        s.arrive(req(2, 100 * 16, 500 * 16)); // beyond 100 blocks
        assert!(s.admit(1));
        assert!(!s.admit(2));
        assert_eq!(s.admissions.len(), 2);
        assert_eq!(
            s.admissions[0],
            Admission::Admitted {
                id: 1,
                cached_tokens: 0
            }
        );
        match s.admissions[1] {
            Admission::KvRejected {
                id,
                demand,
                free,
                reason,
            } => {
                assert_eq!(id, 2);
                assert!(demand > free);
                assert_eq!(reason, RejectReason::KvCapacity);
            }
            _ => panic!("expected KvRejected"),
        }
    }

    #[test]
    fn pause_and_resume_preserve_progress_and_kv() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert!(s.admit(1));
        {
            let r = s.reqs.get_mut(&1).unwrap();
            r.prefill_done = 40;
            r.token_layers_done = 40 * s.model.n_layers as u64;
        }
        assert!(s.pause_prefill(1));
        assert!(s.prefilling.is_empty());
        assert_eq!(s.paused, vec![1]);
        assert_eq!(s.reqs[&1].phase, Phase::Paused);
        assert_eq!(s.kv.len_of(1), Some(110), "KV retained across the pause");
        assert!(!s.pause_prefill(1), "already paused");
        assert!(s.resume_prefill(1));
        assert_eq!(s.prefilling, vec![1]);
        assert!(s.paused.is_empty());
        let r = &s.reqs[&1];
        assert_eq!(r.phase, Phase::Prefilling);
        assert_eq!(r.prefill_done, 40, "progress preserved");
        assert_eq!(r.token_layers_done, 40 * s.model.n_layers as u64);
        // Both transitions were logged for the event stream.
        assert!(s
            .admissions
            .iter()
            .any(|a| matches!(a, Admission::Paused { id: 1, .. })));
        assert!(s
            .admissions
            .iter()
            .any(|a| matches!(a, Admission::Resumed { id: 1 })));
    }

    #[test]
    fn pause_refuses_completed_prefills() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert!(s.admit(1));
        s.reqs.get_mut(&1).unwrap().prefill_done = 100;
        assert!(!s.pause_prefill(1), "nothing left to pause");
        assert!(!s.pause_prefill(99), "unknown id is a no-op");
    }

    #[test]
    fn evict_unfinished_includes_paused() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert!(s.admit(1));
        assert!(s.pause_prefill(1));
        let evicted = s.evict_unfinished();
        assert_eq!(evicted.len(), 1);
        assert!(s.paused.is_empty());
        assert_eq!(s.kv.len_of(1), None, "KV released on eviction");
    }

    #[test]
    fn admission_cost_matches_gross_without_prefix_cache() {
        let s = {
            let mut s = state();
            s.arrive(req(1, 100, 10));
            s
        };
        let (blocks, tokens) = s.admission_cost(1);
        assert_eq!(blocks, s.kv.blocks_for(110));
        assert_eq!(tokens, 100);
    }

    fn tenant_req(id: u64, tenant: u32, input: u32, output: u32) -> Request {
        Request {
            tenant,
            ..req(id, input, output)
        }
    }

    #[test]
    fn tenant_quota_gates_admission_and_releases() {
        use crate::tenant::{TenantRegistry, TenantSpec};
        let mut s = state();
        // Quota of 8 blocks (128 tokens at block size 16).
        let reg = TenantRegistry::new().with(TenantSpec {
            kv_block_quota: 8,
            ..TenantSpec::new(1)
        });
        s.tenants = Some(crate::tenant::TenantAccounting::new(reg));
        s.arrive(tenant_req(1, 1, 100, 10)); // 110 tokens = 7 blocks
        s.arrive(tenant_req(2, 1, 100, 10)); // would be 14 > 8
        s.arrive(tenant_req(3, 2, 100, 10)); // other tenant: unlimited
        assert!(s.admit(1));
        assert!(!s.admit(2), "quota refuses the second admission");
        assert_eq!(s.waiting, vec![2, 3], "refused request stays waiting");
        match s.admissions[1] {
            Admission::KvRejected { id, reason, .. } => {
                assert_eq!(id, 2);
                assert_eq!(reason, RejectReason::TenantQuota);
            }
            _ => panic!("expected KvRejected"),
        }
        assert!(s.admit(3), "unregistered tenants are unlimited");
        // KV untouched by the refusal: only 1 and 3 hold reservations.
        assert_eq!(s.kv.len_of(2), None);
        let acct = s.tenants.as_ref().unwrap();
        assert_eq!(acct.used_blocks(1), 7);
        assert_eq!(acct.used_blocks(2), 7);
        // Finishing releases the charge; the tenant can admit again.
        s.release_kv(1);
        assert_eq!(s.tenants.as_ref().unwrap().used_blocks(1), 0);
        assert!(s.admit(2));
    }

    #[test]
    fn tenant_bucket_gates_prefill_tokens_over_time() {
        use crate::tenant::{TenantRegistry, TenantSpec};
        let mut s = state();
        let reg = TenantRegistry::new().with(TenantSpec {
            rate_tokens_per_s: 50.0,
            burst_tokens: 120.0,
            ..TenantSpec::new(1)
        });
        s.tenants = Some(crate::tenant::TenantAccounting::new(reg));
        s.arrive(tenant_req(1, 1, 100, 10));
        s.arrive(tenant_req(2, 1, 100, 10));
        assert!(s.admit(1), "burst covers the first prompt");
        assert!(!s.admit(2), "bucket drained");
        match s.admissions[1] {
            Admission::KvRejected { reason, .. } => {
                assert_eq!(reason, RejectReason::TenantRate);
            }
            _ => panic!("expected KvRejected"),
        }
        // 2 s of refill = 100 tokens: the retry passes.
        s.now_s = 2.0;
        assert!(s.admit(2));
    }

    #[test]
    fn admit_saturates_on_extreme_footprints() {
        // input + output near u32::MAX must not overflow (debug panic /
        // release wrap into a tiny footprint); it saturates and the KV
        // gate rejects it cleanly.
        let mut s = state();
        s.arrive(req(1, u32::MAX, u32::MAX));
        assert!(!s.admit(1));
        assert!(matches!(
            s.admissions[0],
            Admission::KvRejected { id: 1, .. }
        ));
        assert_eq!(s.waiting, vec![1]);
    }

    #[test]
    fn prefix_credit_shrinks_remaining_prefill() {
        let mut s = state();
        s.kv.enable_prefix_cache();
        let mk = |id: u64| Request {
            id,
            arrival_s: 0.0,
            input_len: 160,
            output_len: 8,
            prefix_id: 7,
            prefix_len: 96, // 6 blocks of 16 shared
            ..Default::default()
        };
        s.arrive(mk(1));
        assert!(s.admit(1));
        // Cold cache: no credit.
        assert_eq!(s.reqs[&1].prefill_done, 0);
        // Before request 1's prefill completes, nothing is hittable — the
        // blocks hold no computed content yet.
        s.arrive(mk(2));
        let hashes = crate::kvcache::shared_block_hashes(&s.reqs[&1].req, s.kv.block_size);
        assert_eq!(hashes.len(), 6, "96 shared tokens = 6 full blocks");
        assert_eq!(s.kv.lookup_prefix(&hashes), 0);
        // Emulate the engine observing request 1's prefill completion: the
        // prompt blocks are published and become hittable.
        assert!(s.kv.publish_prefix(1, &hashes) > 0);
        assert!(s.admit(2));
        // Warm cache: the 6 shared blocks are credited (96 tokens).
        assert_eq!(s.reqs[&2].prefill_done, 96);
        assert_eq!(s.reqs[&2].remaining_prefill(), 64);
        assert_eq!(
            s.reqs[&2].token_layers_done,
            96 * s.model.n_layers as u64
        );
        match s.admissions[1] {
            Admission::Admitted { id, cached_tokens } => {
                assert_eq!((id, cached_tokens), (2, 96));
            }
            _ => panic!("expected Admitted"),
        }
        s.kv.check_invariants().unwrap();
    }

    #[test]
    fn adopt_preserves_progress_through_admission() {
        let mut s = state();
        // A migrated mid-prefill request lands in waiting with progress.
        let mut sim = SimReq::new(req(9, 200, 10));
        sim.prefill_done = 80;
        sim.token_layers_done = 80 * s.model.n_layers as u64;
        s.adopt_waiting(sim);
        assert_eq!(s.waiting, vec![9]);
        assert!(s.admit(9));
        assert_eq!(s.reqs[&9].prefill_done, 80, "admission keeps progress");
        assert_eq!(s.reqs[&9].remaining_prefill(), 120);
        // A migrated fully-prefilled request lands straight in decoding.
        let mut sim = SimReq::new(req(10, 50, 10));
        sim.prefill_done = 50;
        sim.token_layers_done = 50 * s.model.n_layers as u64;
        sim.generated = 4;
        sim.first_token_s = Some(1.0);
        s.adopt_decoding(sim).unwrap();
        assert_eq!(s.decoding, vec![10]);
        assert_eq!(s.reqs[&10].generated, 4);
        assert_eq!(s.reqs[&10].phase, Phase::Decoding);
    }

    #[test]
    fn extract_unfinished_rounds_partial_layer_progress_down() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        assert!(s.admit(1));
        let l = s.model.n_layers as u64;
        // Emulate a layered cohort caught mid-stack: 100 tokens through 3
        // of n_layers layers.
        s.reqs.get_mut(&1).unwrap().token_layers_done = 300;
        let out = s.extract_unfinished();
        assert_eq!(out.len(), 1);
        let (sim, moved) = &out[0];
        assert_eq!(sim.prefill_done as u64, 300 / l);
        assert_eq!(sim.token_layers_done, (300 / l) * l);
        assert_eq!(*moved, s.kv.blocks_for(sim.prefill_done));
        assert_eq!(s.kv.used_blocks(), 0, "source KV released");
    }

    #[test]
    fn requeue_and_eviction_helpers() {
        let mut s = state();
        s.arrive(req(1, 100, 10));
        s.arrive(req(2, 200, 10));
        s.arrive(req(3, 300, 10));
        assert!(s.admit(1));
        // Requeue a waiting request: removed entirely, returned intact.
        let r2 = s.requeue_waiting(2).unwrap();
        assert_eq!((r2.id, r2.input_len), (2, 200));
        assert!(s.requeue_waiting(2).is_none());
        assert!(s.requeue_waiting(1).is_none(), "admitted requests stay put");
        assert_eq!(s.waiting, vec![3]);
        // take_waiting empties the queue in FCFS order.
        let rest = s.take_waiting();
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
        assert!(s.waiting.is_empty());
        // evict_unfinished clears the admitted request and frees its KV.
        assert_eq!(s.kv.len_of(1), Some(110));
        let evicted = s.evict_unfinished();
        assert_eq!(evicted.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert!(s.prefilling.is_empty());
        assert_eq!(s.kv.len_of(1), None);
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn req_table_recycles_slots_and_keeps_map_semantics() {
        let mut t = ReqTable::new();
        assert!(t.is_empty());
        for id in 0..8u64 {
            assert!(t.insert(id, SimReq::new(req(id, 10, 2))).is_none());
        }
        assert_eq!(t.len(), 8);
        assert!(t.contains_key(&3));
        assert_eq!(t[&3].req.input_len, 10);
        // Remove then re-insert: the freed slot is reused, capacity stable.
        let before = t.slots.len();
        assert!(t.remove(&3).is_some());
        assert!(t.remove(&3).is_none());
        assert!(!t.contains_key(&3));
        assert!(t.insert(100, SimReq::new(req(100, 5, 1))).is_none());
        assert_eq!(t.slots.len(), before, "freed slot recycled, no growth");
        assert_eq!(t[&100].req.input_len, 5);
        // Replace semantics match BTreeMap::insert.
        let old = t.insert(100, SimReq::new(req(100, 7, 1))).unwrap();
        assert_eq!(old.req.input_len, 5);
        assert_eq!(t[&100].req.input_len, 7);
        // Iteration covers exactly the live set.
        let mut ids: Vec<u64> = t.iter().map(|(id, _)| id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 4, 5, 6, 7, 100]);
        t.get_mut(&100).unwrap().generated = 1;
        assert_eq!(t[&100].generated, 1);
    }

    #[test]
    fn ctx_len_accounts_generated() {
        let mut r = SimReq::new(req(1, 50, 10));
        assert_eq!(r.ctx_len(), 50);
        r.generated = 3;
        assert_eq!(r.ctx_len(), 53);
        assert_eq!(r.remaining_prefill(), 50);
        r.prefill_done = 20;
        assert_eq!(r.remaining_prefill(), 30);
    }
}
