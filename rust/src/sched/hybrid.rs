//! Hybrid chunked + layered prefill (paper §4.3).
//!
//! The two axes are orthogonal: the prompt is split along the token axis at
//! a LARGE chunk size (default 4096+, enough to push MoE expert GEMMs into
//! the compute-bound regime), and each chunk is then scheduled along the
//! layer axis like layered prefill (G groups, one group per iteration).
//! This inherits chunked-pipeline-parallel's ability to bound in-flight
//! prefill state for very long prompts while retaining layered prefill's
//! single-visit-per-layer expert loading per chunk.
//!
//! Canonical pipeline composition (Policy API v2, bit-identical):
//! `admission=solo, shaper=solo:4096, composer=groups:512` — see
//! [`crate::sched::policy`].

use crate::config::SchedulerConfig;
use crate::sched::{
    groups_for_len, partition_layers, EngineState, GroupPlan, IterationPlan, PrefillWork,
    Scheduler,
};

pub struct HybridChunkedLayered {
    cfg: SchedulerConfig,
    n_layers: u32,
    /// Active request and its current chunk state.
    active: Option<ChunkState>,
}

struct ChunkState {
    req: u64,
    /// Chunk token span [start, start+len).
    start: u32,
    len: u32,
    /// True if this is the prompt's final chunk.
    last_chunk: bool,
    group_sizes: Vec<u32>,
    cursor: usize,
}

impl HybridChunkedLayered {
    pub fn new(cfg: SchedulerConfig, n_layers: u32) -> Self {
        HybridChunkedLayered {
            cfg,
            n_layers,
            active: None,
        }
    }

    fn next_chunk(&mut self, state: &mut EngineState) {
        debug_assert!(self.active.is_none());
        // Continue the current prefilling request if it has tokens left,
        // else admit the next waiting one.
        let id = state
            .prefilling
            .iter()
            .copied()
            .find(|id| state.reqs[id].remaining_prefill() > 0)
            .or_else(|| {
                let head = *state.waiting.first()?;
                let active = state.prefilling.len() + state.decoding.len();
                if active >= state.max_batch.min(self.cfg.max_batch) {
                    return None;
                }
                state.admit(head).then_some(head)
            });
        let Some(id) = id else { return };
        let r = &state.reqs[&id];
        let start = r.prefill_done;
        let len = r.remaining_prefill().min(self.cfg.hybrid_chunk_size);
        let last_chunk = len == r.remaining_prefill();
        let g = groups_for_len(len, self.cfg.group_token_target).min(self.n_layers);
        self.active = Some(ChunkState {
            req: id,
            start,
            len,
            last_chunk,
            group_sizes: partition_layers(self.n_layers, g),
            cursor: 0,
        });
    }
}

impl Scheduler for HybridChunkedLayered {
    fn name(&self) -> &str {
        "hybrid"
    }

    fn plan(&mut self, state: &mut EngineState) -> Option<IterationPlan> {
        if self.active.is_none() {
            self.next_chunk(state);
        }

        let decode = state.decode_set();
        let Some(chunk) = &mut self.active else {
            if decode.is_empty() {
                return None;
            }
            return Some(IterationPlan {
                groups: vec![GroupPlan {
                    n_layers: self.n_layers,
                    prefill: Vec::new(),
                    decode,
                }],
            });
        };

        let last_group = chunk.cursor == chunk.group_sizes.len() - 1;
        let mut groups = Vec::with_capacity(chunk.group_sizes.len());
        for (gi, &gsize) in chunk.group_sizes.iter().enumerate() {
            let prefill = if gi == chunk.cursor {
                vec![PrefillWork {
                    req: chunk.req,
                    tokens: chunk.len,
                    pos: chunk.start,
                    // First token emitted only when the final chunk clears
                    // the final group.
                    completes: last_group && chunk.last_chunk,
                }]
            } else {
                Vec::new()
            };
            groups.push(GroupPlan {
                n_layers: gsize,
                prefill,
                decode: decode.clone(),
            });
        }
        chunk.cursor += 1;
        if last_group {
            self.active = None;
        }
        Some(IterationPlan { groups })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelDesc, Policy};
    use crate::kvcache::KvCacheManager;
    use crate::workload::Request;

    fn setup(hybrid_chunk: u32) -> (HybridChunkedLayered, EngineState) {
        let mut cfg = SchedulerConfig::preset(Policy::Hybrid);
        cfg.hybrid_chunk_size = hybrid_chunk;
        let model = ModelDesc::qwen3_30b_a3b();
        let n = model.n_layers;
        let st = EngineState::new(model, KvCacheManager::new(100_000, 16), 256);
        (HybridChunkedLayered::new(cfg, n), st)
    }

    fn req(id: u64, input: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: 5,
            ..Default::default()
        }
    }

    #[test]
    fn zero_length_prompt_completes_in_one_iteration() {
        let (mut s, mut st) = setup(4096);
        st.arrive(req(1, 0));
        let p = s.plan(&mut st).unwrap();
        // G(0) = 0 clamps to a single full-stack group (partition_layers).
        assert_eq!(p.groups.len(), 1);
        let w = p.groups[0].prefill[0];
        assert_eq!(w.tokens, 0);
        assert!(w.completes);
        assert!(s.active.is_none());
    }

    #[test]
    fn chunks_then_layers() {
        let (mut s, mut st) = setup(4096);
        st.arrive(req(1, 6000));
        // Chunk 1: 4096 tokens, G = 8 -> 8 iterations, no completion.
        for it in 0..8 {
            let p = s.plan(&mut st).unwrap();
            assert_eq!(p.prefill_groups(), 1, "iter {it}");
            let w = p
                .groups
                .iter()
                .find_map(|g| g.prefill.first())
                .copied()
                .unwrap();
            assert_eq!(w.tokens, 4096);
            assert_eq!(w.pos, 0);
            assert!(!w.completes);
        }
        // Engine would record chunk-1 progress.
        st.reqs.get_mut(&1).unwrap().prefill_done = 4096;
        // Chunk 2: 1904 tokens, G = 4 -> completes on 4th.
        for it in 0..4 {
            let p = s.plan(&mut st).unwrap();
            let w = p
                .groups
                .iter()
                .find_map(|g| g.prefill.first())
                .copied()
                .unwrap();
            assert_eq!(w.tokens, 1904);
            assert_eq!(w.pos, 4096);
            assert_eq!(w.completes, it == 3);
        }
    }

    #[test]
    fn short_prompt_one_chunk_g_groups() {
        let (mut s, mut st) = setup(4096);
        st.arrive(req(1, 1024));
        let p = s.plan(&mut st).unwrap();
        assert_eq!(p.groups.len(), 2); // G = ceil(1024/512) = 2
        let _ = s.plan(&mut st).unwrap();
        assert!(s.active.is_none());
    }
}
