//! Unified chaos × property harness: randomized, shrinkable fleet
//! scenarios with committed regression goldens.
//!
//! Every piece of the serving stack has its own property suite, but each
//! one fuzzes its own corner with its own generator and its own ad-hoc
//! assertions. This module unifies them around one value: a serializable
//! [`Scenario`] describing a complete fleet serving run — workload shape,
//! closed-loop session knobs, tenant registry, per-replica policy, router,
//! a chaos schedule of drain/fail/rejoin/scale-up actions, and feature
//! flags (prefix cache, KV migration, thread count).
//!
//! The pipeline:
//!
//! 1. **Generate** ([`generate::from_seed`]) — a seeded, deterministic
//!    draw over the full axis product. Same seed, same scenario, on every
//!    platform at every thread count.
//! 2. **Run** ([`run::run`]) — execute through [`crate::serve::Session`]
//!    with an [`EventLog`](crate::serve::EventLog) sink.
//! 3. **Check** ([`invariants::check_battery`]) — one reusable battery of
//!    conservation laws (see the catalog in [`invariants`]): no request
//!    lost or duplicated, every `Arrived` resolves exactly once, token and
//!    token·layer conservation, prefix-credit conservation, tenant budget
//!    bounds, plan-level I1–I4 via [`crate::sched::audit`], stepped ==
//!    plain, and N-thread byte-identity.
//! 4. **Shrink** ([`shrink::minimize`]) — axis-wise minimization toward
//!    [`Scenario::baseline`]: fewer requests, fewer chaos events, flags
//!    off, one replica.
//! 5. **Commit** ([`regressions`]) — a shrunk counterexample's canonical
//!    JSON goes under `rust/tests/regressions/` and replays forever as a
//!    golden (wired into `tests/chaos_harness.rs` and `lpserve fuzz`).
//!
//! Entry points: `lpserve fuzz --seed S --cases N [--minimize]` on the
//! CLI, `tests/chaos_harness.rs` in the test suite.

pub mod generate;
pub mod invariants;
pub mod regressions;
pub mod run;
pub mod scenario;
pub mod shrink;

pub use generate::from_seed;
pub use invariants::{check_battery, check_outcome, digest_events, digest_report};
pub use run::{run, run_with, Outcome};
pub use scenario::{ChaosEvent, ChaosKind, Scenario, SessionKnobs};
pub use shrink::minimize;
