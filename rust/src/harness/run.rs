//! Execute a [`Scenario`] through [`serve::Session`] and collect everything
//! the invariant battery needs: the [`SessionReport`], the full
//! [`EngineEvent`](crate::serve::EngineEvent) stream, and (for open-loop
//! scenarios) the generated [`Trace`] so per-request budgets are known.
//!
//! [`run_with`] exposes the two levers the battery's differential checks
//! pull: an explicit thread count (byte-identity across counts) and
//! `force_stepped` (attaching an EMPTY [`DrainController`] forces the
//! stepped control-plane path, which must serve identically to the plain
//! path when no chaos actually fires).

use crate::cluster::{build_router, DrainController, ReplicaSpec};
use crate::config::{Dataset, HardwareDesc, ModelDesc, WorkloadSpec};
use crate::sched::PolicySpec;
use crate::serve::{EventLog, Session, SessionReport};
use crate::tenant::TenantRegistry;
use crate::workload::{SessionSource, SessionSpec, Trace, WorkloadGen};

use super::scenario::{ChaosKind, Scenario};

/// Everything one scenario execution produced.
pub struct Outcome {
    pub report: SessionReport,
    /// Full event stream (chronological per replica, merged by the sink).
    pub log: EventLog,
    /// The open-loop trace the run served (`None` for session scenarios,
    /// whose arrivals are generated closed-loop).
    pub trace: Option<Trace>,
    /// Layer count of the model served (for token·layer conservation).
    pub n_layers: u64,
}

/// The open-loop workload spec a scenario denotes (also the base spec for
/// its closed-loop sessions).
pub fn workload_spec(sc: &Scenario) -> WorkloadSpec {
    let dataset = Dataset::parse(&sc.dataset).unwrap_or(Dataset::Fixed);
    let mut spec = WorkloadSpec::new(dataset, sc.rate, sc.n_requests);
    spec.seed = sc.seed;
    spec.fixed_input = sc.fixed_input;
    spec.fixed_output = sc.fixed_output;
    if sc.shared_prefix_len > 0 {
        spec = spec.with_shared_prefix(sc.shared_prefix_len, sc.prefix_groups.max(1));
    }
    if sc.tenant_stamp > 0 {
        spec = spec.with_tenants(sc.tenant_stamp, sc.tenant_heavy_pct);
    }
    if sc.priority_pct > 0 {
        spec = spec.with_priorities(sc.priority_pct);
    }
    spec
}

/// Run the scenario exactly as written.
pub fn run(sc: &Scenario) -> Result<Outcome, String> {
    run_with(sc, sc.threads, false)
}

/// Run the scenario with an overridden thread count and, optionally, the
/// stepped control-plane path forced on (via an empty [`DrainController`])
/// even when the chaos schedule is empty.
pub fn run_with(sc: &Scenario, threads: usize, force_stepped: bool) -> Result<Outcome, String> {
    sc.validate()?;
    let model = ModelDesc::qwen3_30b_a3b();
    let hw = HardwareDesc::h100x2();
    let base = workload_spec(sc);
    let trace = if sc.sessions.is_none() {
        Some(WorkloadGen::new(base.clone()).generate())
    } else {
        None
    };

    let mut log = EventLog::default();
    let report = {
        let mut b = Session::builder()
            .model(model.clone())
            .hardware(hw.clone())
            .threads(threads)
            .control_interval(sc.control_interval_s)
            .prefix_cache(sc.prefix_cache)
            .migrate_kv(sc.migrate_kv);

        if sc.policies.len() == 1 {
            let spec = PolicySpec::parse(&sc.policies[0])?;
            b = b.replicas(sc.replicas).policy_spec(spec);
        } else {
            let specs = sc
                .policies
                .iter()
                .map(|p| {
                    Ok(ReplicaSpec {
                        model: model.clone(),
                        hw: hw.clone(),
                        sched: PolicySpec::parse(p)?.scheduler_config(),
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            b = b.replica_specs(specs);
        }

        let router =
            build_router(&sc.router).ok_or_else(|| format!("unknown router '{}'", sc.router))?;
        b = b.router(router);

        if !sc.tenants.is_empty() {
            b = b.tenants(TenantRegistry::parse(&sc.tenants)?);
        }
        if sc.horizon_s > 0.0 {
            b = b.horizon(sc.horizon_s);
        }

        if !sc.chaos.is_empty() || force_stepped {
            let mut ctl = DrainController::new();
            for ev in &sc.chaos {
                ctl = match ev.kind {
                    ChaosKind::Drain => ctl.drain_at(ev.t_s, ev.replica),
                    ChaosKind::Fail => ctl.fail_at(ev.t_s, ev.replica),
                    ChaosKind::Rejoin => ctl.rejoin_at(ev.t_s, ev.replica),
                    ChaosKind::ScaleUp => ctl.scale_up_at(ev.t_s),
                };
            }
            b = b.controller(ctl);
        }

        b = b.sink(&mut log);
        match (&trace, &sc.sessions) {
            (Some(t), _) => b.trace(t).run(),
            (None, Some(k)) => {
                let spec = SessionSpec::new(base, k.sessions)
                    .exact_turns(k.turns)
                    .think_time_s(k.think_time_s)
                    .followup_tokens(k.followup_tokens)
                    .toolcalls(k.toolcall_pct, k.toolcall_fanout);
                b.workload(SessionSource::new(spec)).run()
            }
            (None, None) => unreachable!("validate() requires a trace or sessions"),
        }
        .map_err(|e| format!("scenario run failed: {e:?}"))?
    };

    Ok(Outcome {
        report,
        log,
        trace,
        n_layers: u64::from(model.n_layers),
    })
}
