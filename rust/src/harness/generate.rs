//! Seeded scenario generator: maps a [`Gen`] draw stream onto the chaos ×
//! property space. Deterministic — the same seed yields the byte-identical
//! scenario on every platform and at every thread count (locked by
//! `tests/chaos_harness.rs`), so a fuzz failure is reproducible from its
//! seed alone.
//!
//! The generator only emits scenarios that pass [`Scenario::validate`]:
//! chaos fires only with ≥ 2 replicas and never darkens replica 0, tenant
//! registries always come with stamped workloads, shared prefixes always
//! carry a group count. Sizes are kept small (≤ 14 requests, ≤ 3 replicas)
//! so a full battery run stays in the tens of milliseconds and shrinking
//! has little distance to travel.

use crate::util::proptest::Gen;

use super::scenario::{ChaosEvent, ChaosKind, Scenario, SessionKnobs};

/// Policy axis: the five presets plus known-valid compact pipeline strings
/// (exercising Policy API v2 admissions, shapers, composers, preemption,
/// and fairness).
const POLICIES: [&str; 9] = [
    "layered",
    "chunked",
    "hybrid",
    "orca",
    "adaptive",
    "admission=srpf,shaper=chunks:512,composer=interleave,preemption=pause",
    "admission=srpt,shaper=chunks:2048,composer=groups:512,preemption=pause:2",
    "admission=cohort:512,shaper=chunks:512,composer=groups:512",
    "fairness=vtfq,weights=1:1+2:4",
];

const ROUTERS: [&str; 5] = ["rr", "least-kv", "slo", "spill", "prefix"];

const TENANT_REGISTRIES: [&str; 3] = [
    "2",
    "1:quota=96;2",
    "1:rate=4000,burst=8000;2:weight=4",
];

/// Generate the scenario a given seed denotes.
pub fn from_seed(seed: u64) -> Scenario {
    let mut g = Gen::new(seed);
    generate(seed, &mut g)
}

/// Draw one scenario from `g`, stamped with `seed` as its identity.
///
/// All numeric fields stay integral or exact halves so the JSON form is
/// canonical (integral floats print as integers; x.5 round-trips exactly).
pub fn generate(seed: u64, g: &mut Gen) -> Scenario {
    let mut sc = Scenario::baseline();
    sc.seed = seed & ((1u64 << 53) - 1);

    sc.replicas = g.usize(1, 3);
    sc.n_requests = g.usize(2, 14);
    sc.rate = g.usize(2, 12) as f64;
    sc.dataset = if g.usize(0, 3) == 0 { "sharegpt" } else { "fixed" }.to_string();
    sc.fixed_input = *g.pick(&[64u32, 256, 512, 1024, 2048]);
    sc.fixed_output = *g.pick(&[4u32, 8, 16, 24]);

    // ~25%: shared system prompts (prefix cache only meaningful then).
    if g.usize(0, 3) == 0 {
        sc.shared_prefix_len = *g.pick(&[256u32, 512, 1024]);
        sc.prefix_groups = g.usize(1, 3) as u32;
        sc.prefix_cache = g.bool();
    }

    // ~25%: tenanted serving with stamped workloads.
    if g.usize(0, 3) == 0 {
        sc.tenants = g.pick(&TENANT_REGISTRIES).to_string();
        sc.tenant_stamp = 2;
        sc.tenant_heavy_pct = *g.pick(&[0u32, 50]);
        // A hard KV quota must stay above any SINGLE request's block
        // footprint: a quota refusal is not time-clearable, so a request
        // that alone exceeds the cap strands in `waiting` and the replica
        // drains without it — a real lost request the conservation law
        // would (correctly) flag. Bound the footprint so quota=96 binds
        // only on concurrency: fixed lengths <= 512+24 tokens (34 blocks),
        // no prefix extension, no unbounded sharegpt tails.
        if sc.tenants.contains("quota") {
            sc.dataset = "fixed".to_string();
            sc.fixed_input = sc.fixed_input.min(512);
            sc.shared_prefix_len = 0;
            sc.prefix_groups = 0;
            sc.prefix_cache = false;
        }
    }
    sc.priority_pct = *g.pick(&[0u32, 0, 30]);

    // Policies: usually fleet-wide, sometimes heterogeneous per replica.
    if sc.replicas > 1 && g.usize(0, 3) == 0 {
        sc.policies = (0..sc.replicas)
            .map(|_| g.pick(&POLICIES).to_string())
            .collect();
    } else {
        sc.policies = vec![g.pick(&POLICIES).to_string()];
    }
    sc.router = g.pick(&ROUTERS).to_string();

    // Chaos needs a survivor: only with >= 2 replicas, never replica 0.
    if sc.replicas >= 2 {
        let n_events = g.usize(0, 2);
        for _ in 0..n_events {
            let kind = *g.pick(&[ChaosKind::Drain, ChaosKind::Fail]);
            let replica = g.usize(1, sc.replicas - 1);
            let t_s = g.usize(1, 12) as f64 * 0.5;
            sc.chaos.push(ChaosEvent { t_s, kind, replica });
            // Half of drains/fails are followed by a rejoin.
            if g.bool() {
                sc.chaos.push(ChaosEvent {
                    t_s: t_s + g.usize(2, 8) as f64 * 0.5,
                    kind: ChaosKind::Rejoin,
                    replica,
                });
            }
        }
        if g.usize(0, 3) == 0 {
            sc.chaos.push(ChaosEvent {
                t_s: g.usize(1, 8) as f64 * 0.5,
                kind: ChaosKind::ScaleUp,
                replica: 0,
            });
        }
        sc.migrate_kv = g.bool();
    }

    // ~25%: closed-loop session intake instead of an open-loop trace.
    if g.usize(0, 3) == 0 {
        sc.sessions = Some(SessionKnobs {
            sessions: g.usize(2, 4),
            turns: g.usize(2, 3) as u32,
            think_time_s: 0.5,
            followup_tokens: 64,
            toolcall_pct: *g.pick(&[0u32, 30]),
            toolcall_fanout: 2,
        });
    }

    sc.threads = *g.pick(&[0usize, 1, 2]);
    sc.control_interval_s = 0.25;
    // Mostly drain to completion; occasionally a bounded horizon so the
    // Halted accounting law gets exercised too.
    sc.horizon_s = if g.usize(0, 3) == 0 { 20.0 } else { 0.0 };

    debug_assert!(sc.validate().is_ok(), "generator emitted invalid scenario");
    sc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_scenarios_are_valid_and_deterministic() {
        for seed in 0..200u64 {
            let a = from_seed(seed);
            a.validate()
                .unwrap_or_else(|e| panic!("seed {seed}: invalid scenario: {e}\n{a:?}"));
            let b = from_seed(seed);
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert_eq!(
                a.to_canonical_string(),
                b.to_canonical_string(),
                "seed {seed} canonical form not byte-stable"
            );
        }
    }

    #[test]
    fn generator_covers_the_axes() {
        let mut saw_chaos = false;
        let mut saw_sessions = false;
        let mut saw_tenants = false;
        let mut saw_prefix = false;
        let mut saw_hetero = false;
        let mut saw_horizon = false;
        for seed in 0..300u64 {
            let sc = from_seed(seed);
            saw_chaos |= !sc.chaos.is_empty();
            saw_sessions |= sc.sessions.is_some();
            saw_tenants |= !sc.tenants.is_empty();
            saw_prefix |= sc.prefix_cache;
            saw_hetero |= sc.policies.len() > 1;
            saw_horizon |= sc.horizon_s > 0.0;
        }
        assert!(
            saw_chaos && saw_sessions && saw_tenants && saw_prefix && saw_hetero && saw_horizon,
            "axis coverage: chaos={saw_chaos} sessions={saw_sessions} tenants={saw_tenants} \
             prefix={saw_prefix} hetero={saw_hetero} horizon={saw_horizon}"
        );
    }
}
