//! Axis-wise scenario shrinking: given a failing [`Scenario`] and a
//! predicate that re-runs the battery, walk the scenario toward
//! [`Scenario::baseline`] one axis at a time, keeping each move only when
//! the failure survives it.
//!
//! Unlike the draw-log integer shrinker in [`crate::util::proptest`], this
//! shrinker understands the scenario's STRUCTURE: it deletes whole chaos
//! events, collapses the fleet to one replica (re-targeting nothing —
//! chaos is dropped first), turns features off wholesale, and bisects the
//! request count — so a violation found in a 14-request, 3-replica,
//! chaotic, tenanted scenario typically lands as a ≤ 4-request, 1-replica,
//! feature-off scenario whose JSON is small enough to read and commit.

use super::scenario::Scenario;

/// One candidate-producing move. Returns `None` when the move does not
/// apply (already minimal on that axis).
type Move = fn(&Scenario) -> Option<Scenario>;

fn drop_last_chaos(sc: &Scenario) -> Option<Scenario> {
    if sc.chaos.is_empty() {
        return None;
    }
    let mut c = sc.clone();
    c.chaos.pop();
    Some(c)
}

fn drop_first_chaos(sc: &Scenario) -> Option<Scenario> {
    if sc.chaos.is_empty() {
        return None;
    }
    let mut c = sc.clone();
    c.chaos.remove(0);
    Some(c)
}

fn clear_chaos(sc: &Scenario) -> Option<Scenario> {
    if sc.chaos.is_empty() {
        return None;
    }
    let mut c = sc.clone();
    c.chaos.clear();
    Some(c)
}

fn one_replica(sc: &Scenario) -> Option<Scenario> {
    if sc.replicas == 1 {
        return None;
    }
    let mut c = sc.clone();
    c.replicas = 1;
    c.policies.truncate(1);
    // Chaos targeting replicas >= 1 can no longer exist; scale-ups would
    // re-grow the fleet. A single-replica repro drops the schedule.
    c.chaos.clear();
    Some(c)
}

fn fewer_replicas(sc: &Scenario) -> Option<Scenario> {
    if sc.replicas <= 2 {
        return None;
    }
    let mut c = sc.clone();
    c.replicas -= 1;
    if c.policies.len() > 1 {
        c.policies.truncate(c.replicas);
    }
    c.chaos.retain(|e| e.replica < c.replicas);
    Some(c)
}

fn no_sessions(sc: &Scenario) -> Option<Scenario> {
    sc.sessions.as_ref()?;
    let mut c = sc.clone();
    c.sessions = None;
    Some(c)
}

fn no_tenants(sc: &Scenario) -> Option<Scenario> {
    if sc.tenants.is_empty() && sc.tenant_stamp == 0 {
        return None;
    }
    let mut c = sc.clone();
    c.tenants.clear();
    c.tenant_stamp = 0;
    c.tenant_heavy_pct = 0;
    Some(c)
}

fn no_priorities(sc: &Scenario) -> Option<Scenario> {
    if sc.priority_pct == 0 {
        return None;
    }
    let mut c = sc.clone();
    c.priority_pct = 0;
    Some(c)
}

fn no_prefixes(sc: &Scenario) -> Option<Scenario> {
    if sc.shared_prefix_len == 0 && !sc.prefix_cache {
        return None;
    }
    let mut c = sc.clone();
    c.shared_prefix_len = 0;
    c.prefix_groups = 0;
    c.prefix_cache = false;
    Some(c)
}

fn no_migration(sc: &Scenario) -> Option<Scenario> {
    if !sc.migrate_kv {
        return None;
    }
    let mut c = sc.clone();
    c.migrate_kv = false;
    Some(c)
}

fn one_thread(sc: &Scenario) -> Option<Scenario> {
    if sc.threads == 1 {
        return None;
    }
    let mut c = sc.clone();
    c.threads = 1;
    Some(c)
}

fn plain_router(sc: &Scenario) -> Option<Scenario> {
    if sc.router == "rr" {
        return None;
    }
    let mut c = sc.clone();
    c.router = "rr".to_string();
    Some(c)
}

fn no_horizon(sc: &Scenario) -> Option<Scenario> {
    if sc.horizon_s == 0.0 {
        return None;
    }
    let mut c = sc.clone();
    c.horizon_s = 0.0;
    Some(c)
}

fn layered_policy(sc: &Scenario) -> Option<Scenario> {
    if sc.policies == ["layered"] {
        return None;
    }
    let mut c = sc.clone();
    c.policies = vec!["layered".to_string()];
    Some(c)
}

fn homogeneous_policies(sc: &Scenario) -> Option<Scenario> {
    if sc.policies.len() <= 1 {
        return None;
    }
    let mut c = sc.clone();
    c.policies.truncate(1);
    Some(c)
}

fn fixed_dataset(sc: &Scenario) -> Option<Scenario> {
    if sc.dataset == "fixed" {
        return None;
    }
    let mut c = sc.clone();
    c.dataset = "fixed".to_string();
    Some(c)
}

fn small_lengths(sc: &Scenario) -> Option<Scenario> {
    if sc.fixed_input <= 64 && sc.fixed_output <= 4 {
        return None;
    }
    let mut c = sc.clone();
    c.fixed_input = 64;
    c.fixed_output = 4;
    Some(c)
}

/// Ordered moves: structure first (chaos, fleet, intake), then feature
/// flags, then sizes. Request-count bisection is handled separately in
/// [`minimize`] because it has multiple candidates per step.
const MOVES: [Move; 16] = [
    clear_chaos,
    one_replica,
    no_sessions,
    no_tenants,
    no_prefixes,
    no_migration,
    no_horizon,
    drop_first_chaos,
    drop_last_chaos,
    fewer_replicas,
    homogeneous_policies,
    layered_policy,
    plain_router,
    no_priorities,
    one_thread,
    fixed_dataset,
];

/// Shrink `sc` to a (locally) minimal scenario on which `fails` still
/// returns `Some(error)`. `fails` must return `Some` for `sc` itself —
/// the returned pair is the minimal scenario and its failure message.
/// `budget` bounds the number of candidate evaluations (each one runs the
/// battery); shrinking stops at a fixpoint or when the budget is spent.
pub fn minimize<F>(sc: &Scenario, fails: F, mut budget: usize) -> (Scenario, String)
where
    F: Fn(&Scenario) -> Option<String>,
{
    let mut best = sc.clone();
    let mut best_msg = match fails(&best) {
        Some(m) => m,
        None => return (best, "minimize: scenario does not fail".to_string()),
    };

    let mut improved = true;
    while improved && budget > 0 {
        improved = false;

        for mv in MOVES {
            if budget == 0 {
                break;
            }
            let Some(cand) = mv(&best) else { continue };
            if cand == best || cand.validate().is_err() {
                continue;
            }
            budget -= 1;
            if let Some(msg) = fails(&cand) {
                best = cand;
                best_msg = msg;
                improved = true;
            }
        }

        // Request-count bisection: try 1, n/4, n/2, n-1 in that order.
        let n = best.n_requests;
        if n > 1 {
            for cand_n in [1, n / 4, n / 2, n - 1] {
                if budget == 0 {
                    break;
                }
                if cand_n == 0 || cand_n >= n {
                    continue;
                }
                let mut cand = best.clone();
                cand.n_requests = cand_n;
                if cand.validate().is_err() {
                    continue;
                }
                budget -= 1;
                if let Some(msg) = fails(&cand) {
                    best = cand;
                    best_msg = msg;
                    improved = true;
                    break;
                }
            }
        }

        // Session-count shrink (when the failure needs sessions).
        if let Some(k) = best.sessions.clone() {
            if k.sessions > 1 && budget > 0 {
                let mut cand = best.clone();
                cand.sessions = Some(super::scenario::SessionKnobs {
                    sessions: 1,
                    turns: k.turns.min(2),
                    toolcall_pct: 0,
                    ..k
                });
                if cand != best && cand.validate().is_ok() {
                    budget -= 1;
                    if let Some(msg) = fails(&cand) {
                        best = cand;
                        best_msg = msg;
                        improved = true;
                    }
                }
            }
        }

        // Length shrink last: a failure that needs long prompts keeps them.
        if budget > 0 {
            if let Some(cand) = small_lengths(&best) {
                if cand.validate().is_ok() {
                    budget -= 1;
                    if let Some(msg) = fails(&cand) {
                        best = cand;
                        best_msg = msg;
                        improved = true;
                    }
                }
            }
        }
    }
    (best, best_msg)
}

#[cfg(test)]
mod tests {
    use super::super::generate;
    use super::*;

    /// An always-failing predicate shrinks any scenario to the floor on
    /// every axis.
    #[test]
    fn always_failing_predicate_reaches_the_floor() {
        for seed in [3u64, 17, 42, 99] {
            let sc = generate::from_seed(seed);
            let (min, msg) = minimize(&sc, |_| Some("boom".to_string()), 400);
            assert_eq!(msg, "boom");
            assert_eq!(min.n_requests, 1, "seed {seed}: {min:?}");
            assert_eq!(min.replicas, 1);
            assert!(min.chaos.is_empty());
            assert!(min.sessions.is_none());
            assert!(min.tenants.is_empty());
            assert!(!min.prefix_cache);
            assert!(!min.migrate_kv);
            assert_eq!(min.policies, vec!["layered".to_string()]);
            assert_eq!(min.router, "rr");
            assert_eq!(min.priority_pct, 0);
            assert_eq!(min.horizon_s, 0.0);
            assert_eq!(min.fixed_input, 64);
            assert_eq!(min.fixed_output, 4);
            min.validate().expect("minimal scenario stays valid");
        }
    }

    /// A predicate that needs tenants AND a chaos event keeps exactly
    /// those axes and shrinks everything else — the acceptance bound:
    /// ≤ 4 requests, ≤ 1 chaos event, ≤ 2 replicas.
    #[test]
    fn structured_predicate_keeps_only_the_needed_axes() {
        let mut found = false;
        for seed in 0..400u64 {
            let sc = generate::from_seed(seed);
            if sc.tenants.is_empty() || sc.chaos.is_empty() {
                continue;
            }
            found = true;
            let fails = |c: &Scenario| {
                if !c.tenants.is_empty() && !c.chaos.is_empty() {
                    Some("needs tenants + chaos".to_string())
                } else {
                    None
                }
            };
            let (min, _) = minimize(&sc, fails, 400);
            assert!(!min.tenants.is_empty());
            assert_eq!(min.chaos.len(), 1, "seed {seed}: {:?}", min.chaos);
            assert!(min.replicas <= 2, "seed {seed}: {} replicas", min.replicas);
            assert!(min.n_requests <= 4, "seed {seed}: {} requests", min.n_requests);
            assert!(min.sessions.is_none());
            assert!(!min.prefix_cache);
            min.validate().expect("minimal scenario stays valid");
        }
        assert!(found, "generator never produced a tenanted chaotic scenario");
    }
}
