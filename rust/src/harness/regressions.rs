//! Committed-regression replay: every `*.json` under
//! `rust/tests/regressions/` is a [`Scenario`] — either a shrunk
//! counterexample from a past fuzz failure (committed alongside its fix)
//! or an exemplar covering an axis combination worth pinning. Replay runs
//! the full invariant battery on each, so a law that once broke can never
//! silently break again.
//!
//! File contract: canonical [`Scenario::to_canonical_string`] bytes plus a
//! trailing newline. The loader re-serializes each file and rejects
//! non-canonical committals — golden files must be diffable and stable
//! under re-emission.

use std::fs;
use std::path::{Path, PathBuf};

use super::invariants;
use super::scenario::Scenario;

/// The in-repo regression directory (`rust/tests/regressions`).
pub fn default_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/regressions")
}

/// Load every `*.json` scenario in `dir`, sorted by file name. Errors name
/// the offending file.
pub fn load_dir(dir: &Path) -> Result<Vec<(String, Scenario)>, String> {
    let mut names: Vec<PathBuf> = fs::read_dir(dir)
        .map_err(|e| format!("read {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|ext| ext == "json"))
        .collect();
    names.sort();

    let mut out = Vec::new();
    for path in names {
        let text = fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let sc = Scenario::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let canonical = format!("{}\n", sc.to_canonical_string());
        if text != canonical {
            return Err(format!(
                "{}: not in canonical form (re-emit with to_canonical_string() + newline)",
                path.display()
            ));
        }
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("scenario")
            .to_string();
        out.push((name, sc));
    }
    Ok(out)
}

/// Replay every committed scenario in `dir` through the full battery.
/// Returns the replayed scenario names; the first failure aborts with the
/// scenario name attached.
pub fn replay(dir: &Path) -> Result<Vec<String>, String> {
    let scenarios = load_dir(dir)?;
    let mut names = Vec::new();
    for (name, sc) in scenarios {
        invariants::check_battery(&sc).map_err(|e| format!("regression '{name}': {e}"))?;
        names.push(name);
    }
    Ok(names)
}
