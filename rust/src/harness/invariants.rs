//! The reusable invariant battery: every conservation law the test suites
//! assert about a serving run, callable on any `(Scenario, Outcome)` pair.
//!
//! One battery, many callers: the chaos fuzzer (`lpserve fuzz`), the
//! committed-regression replayer, `tests/chaos_harness.rs`, and the
//! refactored `tests/serve_events.rs` / `tests/prefix_migration.rs` /
//! `tests/tenant_isolation.rs` suites all check the SAME functions — a law
//! tightened here tightens everywhere at once.
//!
//! The catalog (each law names its checker):
//!
//! * **Token conservation** ([`check_token_conservation`]) — from a
//!   request's LAST `Arrived` onward (re-serves restart the stream):
//!   exactly one `FirstToken`, `output_len − 1` `TokenEmitted`, one
//!   `Finished`.
//! * **Event-stream conservation** ([`check_event_conservation`]) — a
//!   `Drained` run finishes every arrived id exactly once; a `Halted` run
//!   reports at least as many pending as it left unfinished; no id
//!   finishes twice.
//! * **Admission accounting** ([`check_admission_accounting`]) —
//!   admissions only for arrived ids, first `Admitted` after first
//!   `Arrived`; chaos-free drained runs admit every arrival exactly once
//!   with globally unique arrival ids and one `ReplicaDrained` per
//!   replica.
//! * **KV backpressure** ([`check_kv_rejections`]) — every
//!   capacity-reason `KvRejected` carries `demand > free`.
//! * **Prefill-credit conservation** ([`check_prefill_conservation`]) —
//!   computed token·layers plus prefix-credited token·layers equal
//!   `input_len × n_layers` exactly for cleanly-served requests, and
//!   never fall short for re-served/migrated ones.
//! * **Tenant budgets** ([`check_tenant_quota_law`] /
//!   [`check_token_bucket_law`]) — replayed KV-block charges never exceed
//!   a tenant's quota; admitted prefill tokens never outrun
//!   `burst + rate × t`.
//! * **Plan laws I1–I4** ([`check_plan_laws`]) — every policy the
//!   scenario names drives a representative trace through
//!   [`crate::sched::audit::drive_to_drain`].
//! * **Differential identities** (inside [`check_battery`]) — the stepped
//!   control-plane path serves chaos-free scenarios byte-identically to
//!   the plain path, and multi-replica runs are byte-identical at every
//!   thread count (full-fidelity [`digest_events`] / [`digest_report`]).

use std::collections::BTreeMap;

use crate::serve::{EngineEvent, EventLog, SessionReport, SessionStatus};
use crate::tenant::{RejectReason, TenantRegistry};
use crate::workload::{Request, Trace};

use super::run::{self, Outcome};
use super::scenario::Scenario;

// ---------------------------------------------------------------------------
// Full-fidelity digests (every variant, every field — unlike the
// deliberately PR 6-restricted digest tests/tenant_isolation.rs keeps
// locally for its feature-off locks).
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 accumulator over explicitly serialized fields.
pub struct Digest(u64);

impl Digest {
    pub fn new() -> Self {
        Digest(FNV_OFFSET)
    }
    pub fn value(&self) -> u64 {
        self.0
    }
    pub fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }
    pub fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }
    pub fn f64(&mut self, x: f64) {
        self.bytes(&x.to_bits().to_le_bytes());
    }
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
}

impl Default for Digest {
    fn default() -> Self {
        Self::new()
    }
}

fn digest_request(d: &mut Digest, r: &Request) {
    d.u64(r.id);
    d.f64(r.arrival_s);
    d.u64(u64::from(r.input_len));
    d.u64(u64::from(r.output_len));
    d.u64(r.prefix_id);
    d.u64(u64::from(r.prefix_len));
    d.u64(u64::from(r.tenant));
    d.u64(u64::from(r.priority));
}

/// Hash an event stream field-by-field, all variants, all fields.
pub fn digest_events(events: &[(usize, EngineEvent)]) -> u64 {
    let mut d = Digest::new();
    for (replica, ev) in events {
        d.u64(*replica as u64);
        match ev {
            EngineEvent::Arrived { t_s, req } => {
                d.u64(1);
                d.f64(*t_s);
                digest_request(&mut d, req);
            }
            EngineEvent::Admitted { t_s, id } => {
                d.u64(2);
                d.f64(*t_s);
                d.u64(*id);
            }
            EngineEvent::KvRejected {
                t_s,
                id,
                demand,
                free,
                reason,
            } => {
                d.u64(3);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(u64::from(*demand));
                d.u64(u64::from(*free));
                d.str(reason.name());
            }
            EngineEvent::PrefixHit {
                t_s,
                id,
                cached_tokens,
            } => {
                d.u64(4);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(u64::from(*cached_tokens));
            }
            EngineEvent::KvMigrated {
                t_s,
                id,
                from,
                to,
                blocks,
            } => {
                d.u64(5);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(*from as u64);
                d.u64(*to as u64);
                d.u64(u64::from(*blocks));
            }
            EngineEvent::PrefillGroupDone {
                t_s,
                id,
                layers,
                tokens,
            } => {
                d.u64(6);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(u64::from(*layers));
                d.u64(u64::from(*tokens));
            }
            EngineEvent::FirstToken { t_s, id } => {
                d.u64(7);
                d.f64(*t_s);
                d.u64(*id);
            }
            EngineEvent::TokenEmitted { t_s, id, generated } => {
                d.u64(8);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(u64::from(*generated));
            }
            EngineEvent::Finished { t_s, id } => {
                d.u64(9);
                d.f64(*t_s);
                d.u64(*id);
            }
            EngineEvent::ReplicaDrained { t_s } => {
                d.u64(10);
                d.f64(*t_s);
            }
            EngineEvent::ReplicaDown { t_s } => {
                d.u64(11);
                d.f64(*t_s);
            }
            EngineEvent::ReplicaUp { t_s } => {
                d.u64(12);
                d.f64(*t_s);
            }
            EngineEvent::Halted { t_s, pending } => {
                d.u64(13);
                d.f64(*t_s);
                d.u64(*pending as u64);
            }
            EngineEvent::Preempted {
                t_s,
                id,
                resumed_at_layers,
            } => {
                d.u64(14);
                d.f64(*t_s);
                d.u64(*id);
                d.u64(*resumed_at_layers);
            }
            EngineEvent::Resumed { t_s, id } => {
                d.u64(15);
                d.f64(*t_s);
                d.u64(*id);
            }
        }
    }
    d.value()
}

/// Hash a session report: status, routing, policy names, fleet
/// accounting, and per-request timings (tenant included).
pub fn digest_report(rep: &SessionReport) -> u64 {
    let mut d = Digest::new();
    match rep.status {
        SessionStatus::Drained => d.u64(0),
        SessionStatus::Halted { pending } => {
            d.u64(1);
            d.u64(pending as u64);
        }
    }
    for (id, replica) in &rep.assignments {
        d.u64(*id);
        d.u64(*replica as u64);
    }
    for p in &rep.policies {
        d.str(p);
    }
    let m = &rep.fleet;
    d.u64(m.iterations);
    d.f64(m.makespan_s);
    d.f64(m.busy_s);
    d.f64(m.traffic.expert_bytes);
    d.f64(m.traffic.kv_bytes);
    d.f64(m.energy.total_j());
    for r in &m.requests {
        d.u64(r.id);
        d.f64(r.arrival_s);
        d.u64(u64::from(r.input_len));
        d.u64(u64::from(r.output_len));
        d.u64(u64::from(r.tenant));
        d.f64(r.ttft_s);
        d.f64(r.finish_s);
        for t in &r.tbts_s {
            d.f64(*t);
        }
    }
    d.value()
}

// ---------------------------------------------------------------------------
// Event-stream helpers shared with the test suites.
// ---------------------------------------------------------------------------

/// Token·layers of prefill computed for `id` across the whole log
/// (`PrefillGroupDone` tokens × layers, summed).
pub fn prefill_token_layers(log: &EventLog, id: u64) -> u64 {
    log.events
        .iter()
        .map(|(_, e)| match e {
            EngineEvent::PrefillGroupDone {
                id: eid,
                layers,
                tokens,
                ..
            } if *eid == id => u64::from(*tokens) * u64::from(*layers),
            _ => 0,
        })
        .sum()
}

/// Prompt tokens credited to `id` from prefix-cache hits (`PrefixHit`
/// cached_tokens, summed — each credited token skips ALL layers).
pub fn credited_tokens(log: &EventLog, id: u64) -> u64 {
    log.events
        .iter()
        .map(|(_, e)| match e {
            EngineEvent::PrefixHit {
                id: eid,
                cached_tokens,
                ..
            } if *eid == id => u64::from(*cached_tokens),
            _ => 0,
        })
        .sum()
}

/// Per-request view assembled from the log: the `Request` payload of the
/// last `Arrived`, event indices, and counters over the events from the
/// last `Arrived` onward (the window conservation laws apply to).
struct ReqView {
    req: Request,
    arrivals: usize,
    last_arrived_idx: usize,
    admitted_after: usize,
    first_tokens_after: usize,
    tokens_after: usize,
    finished_after: usize,
    finished_total: usize,
    migrations: usize,
    admitted_total: usize,
    first_admitted_idx: Option<usize>,
    first_arrived_idx: usize,
}

fn views(log: &EventLog) -> BTreeMap<u64, ReqView> {
    let mut m: BTreeMap<u64, ReqView> = BTreeMap::new();
    for (idx, (_, ev)) in log.events.iter().enumerate() {
        if let EngineEvent::Arrived { req, .. } = ev {
            m.entry(req.id)
                .and_modify(|v| {
                    v.arrivals += 1;
                    v.last_arrived_idx = idx;
                    v.req = *req;
                    // Window counters restart at a fresh arrival.
                    v.admitted_after = 0;
                    v.first_tokens_after = 0;
                    v.tokens_after = 0;
                    v.finished_after = 0;
                })
                .or_insert(ReqView {
                    req: *req,
                    arrivals: 1,
                    last_arrived_idx: idx,
                    admitted_after: 0,
                    first_tokens_after: 0,
                    tokens_after: 0,
                    finished_after: 0,
                    finished_total: 0,
                    migrations: 0,
                    admitted_total: 0,
                    first_admitted_idx: None,
                    first_arrived_idx: idx,
                });
            continue;
        }
        let Some(id) = ev.id() else { continue };
        let Some(v) = m.get_mut(&id) else { continue };
        match ev {
            EngineEvent::Admitted { .. } => {
                v.admitted_after += 1;
                v.admitted_total += 1;
                v.first_admitted_idx.get_or_insert(idx);
            }
            EngineEvent::FirstToken { .. } => v.first_tokens_after += 1,
            EngineEvent::TokenEmitted { .. } => v.tokens_after += 1,
            EngineEvent::Finished { .. } => {
                v.finished_after += 1;
                v.finished_total += 1;
            }
            EngineEvent::KvMigrated { .. } => v.migrations += 1,
            _ => {}
        }
    }
    m
}

/// Events referencing an id that never arrived indicate sink corruption.
fn orphan_check(log: &EventLog) -> Result<(), String> {
    let mut arrived: BTreeMap<u64, bool> = BTreeMap::new();
    for (_, ev) in &log.events {
        if let EngineEvent::Arrived { req, .. } = ev {
            arrived.insert(req.id, true);
        } else if let Some(id) = ev.id() {
            if !arrived.contains_key(&id) {
                return Err(format!(
                    "event {ev:?} references request {id} before/without any Arrived"
                ));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The laws.
// ---------------------------------------------------------------------------

/// From each request's last `Arrived` onward: a finished request has
/// exactly one `FirstToken`, `output_len − 1` `TokenEmitted`, and one
/// `Finished`.
pub fn check_token_conservation(log: &EventLog) -> Result<(), String> {
    for (id, v) in views(log) {
        if v.finished_after == 0 {
            continue;
        }
        if v.finished_after != 1 {
            return Err(format!(
                "req {id}: {} Finished after last Arrived (want 1)",
                v.finished_after
            ));
        }
        if v.first_tokens_after != 1 {
            return Err(format!(
                "req {id}: {} FirstToken after last Arrived (want 1)",
                v.first_tokens_after
            ));
        }
        let want = v.req.output_len.max(1) as usize - 1;
        if v.tokens_after != want {
            return Err(format!(
                "req {id}: {} TokenEmitted after last Arrived (want {want} = output_len-1)",
                v.tokens_after
            ));
        }
    }
    Ok(())
}

/// `Arrived` resolution: a `Drained` run finishes every arrived id exactly
/// once (globally — a request only truly finishes once, even re-served);
/// a `Halted` run leaves `pending` ≥ the unfinished arrivals. No id ever
/// finishes twice.
pub fn check_event_conservation(log: &EventLog, status: SessionStatus) -> Result<(), String> {
    orphan_check(log)?;
    let vs = views(log);
    let mut unfinished = 0usize;
    for (id, v) in &vs {
        if v.finished_total > 1 {
            return Err(format!(
                "req {id}: finished {} times (a request finishes once)",
                v.finished_total
            ));
        }
        if v.finished_total == 0 {
            unfinished += 1;
            if status == SessionStatus::Drained {
                return Err(format!("req {id}: arrived but never Finished in a Drained run"));
            }
        }
    }
    if let SessionStatus::Halted { pending } = status {
        if pending < unfinished {
            return Err(format!(
                "Halted reports {pending} pending but {unfinished} arrived ids are unfinished"
            ));
        }
    }
    Ok(())
}

/// Admission accounting. Always: admissions only for arrived ids (orphan
/// check) and the first `Admitted` follows the first `Arrived`. For
/// chaos-free drained runs additionally: every id arrives exactly once,
/// is admitted exactly once, and each of the fleet's `n_replicas` emits
/// exactly one `ReplicaDrained`.
pub fn check_admission_accounting(
    log: &EventLog,
    status: SessionStatus,
    chaos_free: bool,
    n_replicas: usize,
) -> Result<(), String> {
    let vs = views(log);
    for (id, v) in &vs {
        if let Some(adm) = v.first_admitted_idx {
            if adm < v.first_arrived_idx {
                return Err(format!("req {id}: Admitted at index {adm} before Arrived"));
            }
        }
        if v.finished_total > 0 && v.admitted_total == 0 {
            return Err(format!("req {id}: Finished without any Admitted"));
        }
    }
    if chaos_free && status == SessionStatus::Drained {
        for (id, v) in &vs {
            if v.arrivals != 1 {
                return Err(format!(
                    "req {id}: {} Arrived events in a chaos-free run (want 1)",
                    v.arrivals
                ));
            }
            if v.admitted_total != 1 {
                return Err(format!(
                    "req {id}: {} Admitted events in a chaos-free drained run (want 1)",
                    v.admitted_total
                ));
            }
        }
        let drained = log.count(|e| matches!(e, EngineEvent::ReplicaDrained { .. }));
        if drained != n_replicas {
            return Err(format!(
                "{drained} ReplicaDrained events for {n_replicas} replicas"
            ));
        }
    }
    Ok(())
}

/// Every capacity-reason `KvRejected` is a real shortfall: demand > free.
pub fn check_kv_rejections(log: &EventLog) -> Result<(), String> {
    for (_, ev) in &log.events {
        if let EngineEvent::KvRejected {
            id,
            demand,
            free,
            reason: RejectReason::KvCapacity,
            ..
        } = ev
        {
            if demand <= free {
                return Err(format!(
                    "req {id}: KvCapacity rejection with demand {demand} <= free {free}"
                ));
            }
        }
    }
    Ok(())
}

/// Prefill-credit conservation against `want = input_len × n_layers`:
///
/// * cleanly-served ids (one `Arrived`, one `Admitted`, no `KvMigrated`):
///   computed + credited × n_layers == want at finish, ≤ want before;
/// * re-served / migrated ids that finished: ≥ want (migration resumes
///   with zero recompute — exactly `want`; a from-scratch re-serve
///   recomputes — strictly more);
/// * every id: computed work never exceeds one full prefill per serving
///   attempt (`arrivals + migrations` bounds the multiplier).
pub fn check_prefill_conservation(log: &EventLog, n_layers: u64) -> Result<(), String> {
    for (id, v) in views(log) {
        let want = u64::from(v.req.input_len) * n_layers;
        let computed = prefill_token_layers(log, id);
        let credited = credited_tokens(log, id) * n_layers;
        let clean = v.arrivals == 1 && v.admitted_total <= 1 && v.migrations == 0;
        if clean {
            if v.finished_total > 0 && computed + credited != want {
                return Err(format!(
                    "req {id}: computed {computed} + credited {credited} token-layers != {want} \
                     (input {} x {n_layers} layers) on a clean serve",
                    v.req.input_len
                ));
            }
            if computed + credited > want {
                return Err(format!(
                    "req {id}: computed {computed} + credited {credited} token-layers > {want} \
                     (over-prefill on a clean serve)"
                ));
            }
        } else {
            if v.finished_total > 0 && computed + credited < want {
                return Err(format!(
                    "req {id}: computed {computed} + credited {credited} token-layers < {want} \
                     after {} arrivals / {} migrations — finished under-prefilled",
                    v.arrivals, v.migrations
                ));
            }
            let attempts = (v.arrivals + v.migrations) as u64;
            if computed > want.saturating_mul(attempts.max(1)) {
                return Err(format!(
                    "req {id}: computed {computed} token-layers exceeds {attempts} full prefills \
                     of {want}"
                ));
            }
        }
    }
    Ok(())
}

/// Replay KV-block charges per tenant from the event stream: blocks
/// concurrently charged to a tenant never exceed its quota. Valid for
/// single-replica, chaos-free, prefix-cache-off runs over an open-loop
/// trace with the default 16-token KV block size (the conditions
/// `tests/tenant_isolation.rs` locks).
pub fn check_tenant_quota_law(
    log: &EventLog,
    trace: &Trace,
    reg: &TenantRegistry,
) -> Result<(), String> {
    let by_id: BTreeMap<u64, &Request> = trace.requests.iter().map(|r| (r.id, r)).collect();
    let blocks_for =
        |r: &Request| (u64::from(r.input_len) + u64::from(r.output_len)).div_ceil(16);
    for tenant in reg.ids() {
        let quota = reg.spec(tenant).kv_block_quota;
        if quota == 0 {
            continue;
        }
        let mut charged: u64 = 0;
        let mut peak: u64 = 0;
        for (_, ev) in &log.events {
            match ev {
                EngineEvent::Admitted { id, .. } => {
                    if let Some(r) = by_id.get(id).filter(|r| r.tenant == tenant) {
                        charged += blocks_for(r);
                        peak = peak.max(charged);
                    }
                }
                EngineEvent::Finished { id, .. } => {
                    if let Some(r) = by_id.get(id).filter(|r| r.tenant == tenant) {
                        charged = charged.saturating_sub(blocks_for(r));
                    }
                }
                _ => {}
            }
        }
        if peak > quota {
            return Err(format!(
                "tenant {tenant}: peak KV charge {peak} blocks > quota {quota}"
            ));
        }
    }
    Ok(())
}

/// Replay token-bucket admission per tenant: cumulative admitted prefill
/// tokens never exceed `burst + rate × t + 0.5`. Same validity conditions
/// as [`check_tenant_quota_law`].
pub fn check_token_bucket_law(
    log: &EventLog,
    trace: &Trace,
    reg: &TenantRegistry,
) -> Result<(), String> {
    let by_id: BTreeMap<u64, &Request> = trace.requests.iter().map(|r| (r.id, r)).collect();
    for tenant in reg.ids() {
        let spec = reg.spec(tenant);
        if spec.rate_tokens_per_s <= 0.0 {
            continue;
        }
        let burst = if spec.burst_tokens > 0.0 {
            spec.burst_tokens
        } else {
            spec.rate_tokens_per_s
        };
        let mut admitted_tokens = 0.0f64;
        for (_, ev) in &log.events {
            if let EngineEvent::Admitted { t_s, id } = ev {
                let Some(r) = by_id.get(id).filter(|r| r.tenant == tenant) else {
                    continue;
                };
                // The bucket clamps each charge to its capacity (a prompt
                // larger than burst drains the full bucket, no more), so
                // the conserved quantity is the clamped sum.
                admitted_tokens += f64::from(r.input_len).min(burst);
                let bound = burst + spec.rate_tokens_per_s * *t_s + 0.5;
                if admitted_tokens > bound {
                    return Err(format!(
                        "tenant {tenant}: {admitted_tokens} bucket-charged prefill tokens \
                         admitted by t={t_s:.3}s, bound {bound:.1} (rate {}, burst {burst})",
                        spec.rate_tokens_per_s
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Drive every policy the scenario names through the plan-level I1–I4
/// auditor ([`crate::sched::audit`]) over the scenario's own workload.
pub fn check_plan_laws(sc: &Scenario) -> Result<(), String> {
    use crate::config::ModelDesc;
    use crate::sched::PolicySpec;
    use crate::workload::WorkloadGen;

    let model = ModelDesc::qwen3_30b_a3b();
    let trace = WorkloadGen::new(run::workload_spec(sc)).generate();
    let arrivals: Vec<(Request, usize)> = trace
        .requests
        .iter()
        .enumerate()
        .map(|(i, r)| (*r, i))
        .collect();
    let mut seen: Vec<&str> = Vec::new();
    for p in &sc.policies {
        if seen.contains(&p.as_str()) {
            continue;
        }
        seen.push(p);
        let cfg = PolicySpec::parse(p)?.scheduler_config();
        crate::sched::audit::drive_to_drain(&cfg, &model, &arrivals)
            .map_err(|e| format!("plan laws (policy '{p}'): {e}"))?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Composition.
// ---------------------------------------------------------------------------

/// All single-run laws over one executed scenario.
pub fn check_outcome(sc: &Scenario, out: &Outcome) -> Result<(), String> {
    let chaos_free = sc.chaos.is_empty();
    check_event_conservation(&out.log, out.report.status)?;
    check_token_conservation(&out.log)?;
    check_admission_accounting(
        &out.log,
        out.report.status,
        chaos_free,
        out.report.per_replica.len(),
    )?;
    check_kv_rejections(&out.log)?;
    check_prefill_conservation(&out.log, out.n_layers)?;
    if let Some(trace) = &out.trace {
        if !sc.tenants.is_empty() && sc.replicas == 1 && chaos_free && !sc.prefix_cache {
            let reg = TenantRegistry::parse(&sc.tenants)?;
            check_tenant_quota_law(&out.log, trace, &reg)?;
            check_token_bucket_law(&out.log, trace, &reg)?;
        }
    }
    Ok(())
}

/// The full battery: run the scenario, check every single-run law, then
/// the differential identities (stepped == plain for chaos-free open-loop
/// scenarios; thread-count byte-identity for multi-replica fleets), then
/// the plan laws for every named policy.
pub fn check_battery(sc: &Scenario) -> Result<(), String> {
    let out = run::run(sc)?;
    check_outcome(sc, &out)?;

    if sc.chaos.is_empty() && sc.sessions.is_none() {
        let stepped = run::run_with(sc, sc.threads, true)?;
        if digest_events(&stepped.log.events) != digest_events(&out.log.events)
            || digest_report(&stepped.report) != digest_report(&out.report)
        {
            return Err(
                "stepped control-plane path diverged from the plain path on a chaos-free \
                 scenario"
                    .to_string(),
            );
        }
    }

    if sc.replicas > 1 {
        let serial = run::run_with(sc, 1, false)?;
        let threaded = run::run_with(sc, 2, false)?;
        if digest_events(&serial.log.events) != digest_events(&threaded.log.events)
            || digest_report(&serial.report) != digest_report(&threaded.report)
        {
            return Err("event stream not byte-identical across thread counts".to_string());
        }
    }

    check_plan_laws(sc)?;
    Ok(())
}
