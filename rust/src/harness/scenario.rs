//! The serializable [`Scenario`] value: one point in the chaos × property
//! space — workload shape × closed-loop session knobs × tenant registry ×
//! per-replica policy × router × chaos schedule × feature flags.
//!
//! A scenario is data, not code: it round-trips through
//! [`crate::util::json`] byte-stably ([`Scenario::to_canonical_string`] ∘
//! [`Scenario::parse`] is the identity on canonical strings — object keys
//! are `BTreeMap`-sorted and integral numbers print as integers), so a
//! shrunk counterexample can be committed under `rust/tests/regressions/`
//! and replayed forever. [`Scenario::validate`] is the single gate both
//! the generator and the regression loader go through: every policy
//! string must parse, the router must exist, chaos events must target
//! real replicas and never take the whole fleet down.

use std::collections::BTreeMap;

use crate::cluster::build_router;
use crate::sched::PolicySpec;
use crate::tenant::TenantRegistry;
use crate::util::json::{self, Json};

/// One scripted control-plane action at `t_s` engine seconds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Graceful drain: stop routing, let in-flight work finish/migrate.
    Drain,
    /// Hard failure: the replica dies; admitted work re-serves or migrates.
    Fail,
    /// A drained/failed replica re-enters rotation.
    Rejoin,
    /// The fleet grows by one fresh replica (ignores `replica`).
    ScaleUp,
}

impl ChaosKind {
    pub fn name(self) -> &'static str {
        match self {
            ChaosKind::Drain => "drain",
            ChaosKind::Fail => "fail",
            ChaosKind::Rejoin => "rejoin",
            ChaosKind::ScaleUp => "scale-up",
        }
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "drain" => Ok(ChaosKind::Drain),
            "fail" => Ok(ChaosKind::Fail),
            "rejoin" => Ok(ChaosKind::Rejoin),
            "scale-up" => Ok(ChaosKind::ScaleUp),
            other => Err(format!(
                "unknown chaos kind '{other}' (drain|fail|rejoin|scale-up)"
            )),
        }
    }
}

/// One chaos-schedule entry: `kind` fires at `t_s` against `replica`
/// (ignored by [`ChaosKind::ScaleUp`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosEvent {
    pub t_s: f64,
    pub kind: ChaosKind,
    pub replica: usize,
}

impl ChaosEvent {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind.name().to_string()));
        m.insert("replica".to_string(), Json::Num(self.replica as f64));
        m.insert("t_s".to_string(), Json::Num(self.t_s));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(ChaosEvent {
            t_s: req_f64(j, "t_s")?,
            kind: ChaosKind::parse(req_str(j, "kind")?)?,
            replica: req_f64(j, "replica")? as usize,
        })
    }
}

/// Closed-loop session intake knobs (`None` = open-loop trace workload).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionKnobs {
    /// Concurrent multi-turn conversations.
    pub sessions: usize,
    /// Exact main-chain turns per session.
    pub turns: u32,
    /// Think-time gap between a finish and the next turn's arrival.
    pub think_time_s: f64,
    /// Fresh user tokens appended per follow-up turn (0 = sampled).
    pub followup_tokens: u32,
    /// Percent of turns fanning out tool-call children.
    pub toolcall_pct: u32,
    /// Children per tool-call turn.
    pub toolcall_fanout: u32,
}

impl SessionKnobs {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sessions".to_string(), Json::Num(self.sessions as f64));
        m.insert("turns".to_string(), Json::Num(f64::from(self.turns)));
        m.insert("think_time_s".to_string(), Json::Num(self.think_time_s));
        m.insert(
            "followup_tokens".to_string(),
            Json::Num(f64::from(self.followup_tokens)),
        );
        m.insert(
            "toolcall_pct".to_string(),
            Json::Num(f64::from(self.toolcall_pct)),
        );
        m.insert(
            "toolcall_fanout".to_string(),
            Json::Num(f64::from(self.toolcall_fanout)),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        Ok(SessionKnobs {
            sessions: req_f64(j, "sessions")? as usize,
            turns: req_f64(j, "turns")? as u32,
            think_time_s: req_f64(j, "think_time_s")?,
            followup_tokens: req_f64(j, "followup_tokens")? as u32,
            toolcall_pct: req_f64(j, "toolcall_pct")? as u32,
            toolcall_fanout: req_f64(j, "toolcall_fanout")? as u32,
        })
    }
}

/// A complete, serializable description of one fleet serving run — the
/// unit the chaos harness generates, runs, checks, shrinks, and commits.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Workload RNG seed (also the scenario's identity in fuzz output).
    pub seed: u64,
    /// `fixed` | `sharegpt` | `arxiv`.
    pub dataset: String,
    /// Open-loop request count (ignored when `sessions` is set).
    pub n_requests: usize,
    /// Mean arrival rate, req/s.
    pub rate: f64,
    /// Prompt tokens for the `fixed` dataset.
    pub fixed_input: u32,
    /// Output tokens for the `fixed` dataset.
    pub fixed_output: u32,
    /// Shared system-prompt prefix length (0 = no shared prefixes).
    pub shared_prefix_len: u32,
    /// Distinct prefix groups when `shared_prefix_len > 0`.
    pub prefix_groups: u32,
    /// Tenant registry in the CLI `--tenants` grammar ("" = untenanted).
    pub tenants: String,
    /// Tenant ids stamped on the workload (0 = leave untenanted).
    pub tenant_stamp: u32,
    /// Percent of arrivals given to tenant 1 (noisy neighbor; 0 = uniform).
    pub tenant_heavy_pct: u32,
    /// Percent of requests stamped priority 1.
    pub priority_pct: u32,
    /// Closed-loop session knobs (`None` = open-loop trace).
    pub sessions: Option<SessionKnobs>,
    /// Fleet size at start.
    pub replicas: usize,
    /// Per-replica `PolicySpec` strings: one entry applies fleet-wide,
    /// otherwise exactly one per replica.
    pub policies: Vec<String>,
    /// Router name (`rr` | `least-kv` | `slo` | `spill` | `prefix`).
    pub router: String,
    /// Scripted drain/fail/rejoin/scale-up schedule.
    pub chaos: Vec<ChaosEvent>,
    /// Automatic prefix caching on/off.
    pub prefix_cache: bool,
    /// KV migration on drain/fail on/off.
    pub migrate_kv: bool,
    /// Worker threads (0 = auto; byte-identical at every count).
    pub threads: usize,
    /// Control boundary interval, seconds.
    pub control_interval_s: f64,
    /// Run horizon (0 = drain to completion).
    pub horizon_s: f64,
}

impl Scenario {
    /// The smallest interesting scenario: one replica, one tiny fixed
    /// workload, every feature off. Shrinking converges toward this.
    pub fn baseline() -> Self {
        Scenario {
            seed: 1,
            dataset: "fixed".to_string(),
            n_requests: 2,
            rate: 4.0,
            fixed_input: 64,
            fixed_output: 4,
            shared_prefix_len: 0,
            prefix_groups: 0,
            tenants: String::new(),
            tenant_stamp: 0,
            tenant_heavy_pct: 0,
            priority_pct: 0,
            sessions: None,
            replicas: 1,
            policies: vec!["layered".to_string()],
            router: "rr".to_string(),
            chaos: Vec::new(),
            prefix_cache: false,
            migrate_kv: false,
            threads: 1,
            control_interval_s: 0.25,
            horizon_s: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "chaos".to_string(),
            Json::Arr(self.chaos.iter().map(ChaosEvent::to_json).collect()),
        );
        m.insert(
            "control_interval_s".to_string(),
            Json::Num(self.control_interval_s),
        );
        m.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        m.insert(
            "fixed_input".to_string(),
            Json::Num(f64::from(self.fixed_input)),
        );
        m.insert(
            "fixed_output".to_string(),
            Json::Num(f64::from(self.fixed_output)),
        );
        m.insert("horizon_s".to_string(), Json::Num(self.horizon_s));
        m.insert("migrate_kv".to_string(), Json::Bool(self.migrate_kv));
        m.insert("n_requests".to_string(), Json::Num(self.n_requests as f64));
        m.insert(
            "policies".to_string(),
            Json::Arr(
                self.policies
                    .iter()
                    .map(|p| Json::Str(p.clone()))
                    .collect(),
            ),
        );
        m.insert(
            "prefix_cache".to_string(),
            Json::Bool(self.prefix_cache),
        );
        m.insert(
            "prefix_groups".to_string(),
            Json::Num(f64::from(self.prefix_groups)),
        );
        m.insert(
            "priority_pct".to_string(),
            Json::Num(f64::from(self.priority_pct)),
        );
        m.insert("rate".to_string(), Json::Num(self.rate));
        m.insert("replicas".to_string(), Json::Num(self.replicas as f64));
        m.insert("router".to_string(), Json::Str(self.router.clone()));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert(
            "sessions".to_string(),
            match &self.sessions {
                Some(k) => k.to_json(),
                None => Json::Null,
            },
        );
        m.insert(
            "shared_prefix_len".to_string(),
            Json::Num(f64::from(self.shared_prefix_len)),
        );
        m.insert(
            "tenant_heavy_pct".to_string(),
            Json::Num(f64::from(self.tenant_heavy_pct)),
        );
        m.insert(
            "tenant_stamp".to_string(),
            Json::Num(f64::from(self.tenant_stamp)),
        );
        m.insert("tenants".to_string(), Json::Str(self.tenants.clone()));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let chaos = match j.get("chaos") {
            Some(Json::Arr(v)) => v
                .iter()
                .map(ChaosEvent::from_json)
                .collect::<Result<Vec<_>, _>>()?,
            Some(other) => return Err(format!("chaos: expected array, got {other:?}")),
            None => Vec::new(),
        };
        let sessions = match j.get("sessions") {
            None | Some(Json::Null) => None,
            Some(k) => Some(SessionKnobs::from_json(k)?),
        };
        let policies = match j.get("policies") {
            Some(Json::Arr(v)) => v
                .iter()
                .map(|p| {
                    p.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("policies: expected string, got {p:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            _ => return Err("policies: expected array of strings".to_string()),
        };
        let sc = Scenario {
            seed: req_f64(j, "seed")? as u64,
            dataset: req_str(j, "dataset")?.to_string(),
            n_requests: req_f64(j, "n_requests")? as usize,
            rate: req_f64(j, "rate")?,
            fixed_input: req_f64(j, "fixed_input")? as u32,
            fixed_output: req_f64(j, "fixed_output")? as u32,
            shared_prefix_len: req_f64(j, "shared_prefix_len")? as u32,
            prefix_groups: req_f64(j, "prefix_groups")? as u32,
            tenants: req_str(j, "tenants")?.to_string(),
            tenant_stamp: req_f64(j, "tenant_stamp")? as u32,
            tenant_heavy_pct: req_f64(j, "tenant_heavy_pct")? as u32,
            priority_pct: req_f64(j, "priority_pct")? as u32,
            sessions,
            replicas: req_f64(j, "replicas")? as usize,
            policies,
            router: req_str(j, "router")?.to_string(),
            chaos,
            prefix_cache: req_bool(j, "prefix_cache")?,
            migrate_kv: req_bool(j, "migrate_kv")?,
            threads: req_f64(j, "threads")? as usize,
            control_interval_s: req_f64(j, "control_interval_s")?,
            horizon_s: req_f64(j, "horizon_s")?,
        };
        sc.validate()?;
        Ok(sc)
    }

    /// Canonical serialized form: sorted keys, integral numbers printed
    /// as integers. `parse(to_canonical_string())` reproduces the exact
    /// bytes — the property `tests/chaos_harness.rs` locks.
    pub fn to_canonical_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        let j = json::parse(s).map_err(|e| format!("scenario JSON: {e}"))?;
        Self::from_json(&j)
    }

    /// Structural validity: every axis value must be runnable before the
    /// harness builds a `serve::Session` from it.
    pub fn validate(&self) -> Result<(), String> {
        if self.seed >= (1u64 << 53) {
            return Err("seed must fit in an f64-exact integer (< 2^53)".to_string());
        }
        if !matches!(self.dataset.as_str(), "fixed" | "sharegpt" | "arxiv") {
            return Err(format!(
                "unknown dataset '{}' (fixed|sharegpt|arxiv)",
                self.dataset
            ));
        }
        if self.replicas == 0 || self.replicas > 8 {
            return Err(format!("replicas {} out of range 1..=8", self.replicas));
        }
        if self.sessions.is_none() && self.n_requests == 0 {
            return Err("open-loop scenario needs n_requests >= 1".to_string());
        }
        if let Some(k) = &self.sessions {
            if k.sessions == 0 || k.turns == 0 {
                return Err("session scenario needs sessions >= 1 and turns >= 1".to_string());
            }
        }
        if self.rate <= 0.0 {
            return Err(format!("rate {} must be positive", self.rate));
        }
        if self.policies.is_empty() {
            return Err("at least one policy is required".to_string());
        }
        if self.policies.len() != 1 && self.policies.len() != self.replicas {
            return Err(format!(
                "{} policies for {} replicas (need 1 or exactly one per replica)",
                self.policies.len(),
                self.replicas
            ));
        }
        for p in &self.policies {
            PolicySpec::parse(p).map_err(|e| format!("policy '{p}': {e}"))?;
        }
        if build_router(&self.router).is_none() {
            return Err(format!("unknown router '{}'", self.router));
        }
        if !self.tenants.is_empty() {
            TenantRegistry::parse(&self.tenants)
                .map_err(|e| format!("tenants '{}': {e}", self.tenants))?;
            if self.tenant_stamp == 0 {
                return Err(
                    "a tenant registry without stamped tenant ids enforces nothing".to_string(),
                );
            }
        }
        if self.shared_prefix_len > 0 && self.prefix_groups == 0 {
            return Err("shared_prefix_len > 0 needs prefix_groups >= 1".to_string());
        }
        let scale_ups = self
            .chaos
            .iter()
            .filter(|e| e.kind == ChaosKind::ScaleUp)
            .count();
        for ev in &self.chaos {
            if ev.t_s < 0.0 {
                return Err(format!("chaos event at negative time {}", ev.t_s));
            }
            if ev.kind != ChaosKind::ScaleUp && ev.replica >= self.replicas + scale_ups {
                return Err(format!(
                    "chaos {} targets replica {} of {} (+{} scale-ups)",
                    ev.kind.name(),
                    ev.replica,
                    self.replicas,
                    scale_ups
                ));
            }
            // Keep at least one replica serving: scripted drains/fails must
            // never touch replica 0, so the fleet cannot go fully dark.
            if matches!(ev.kind, ChaosKind::Drain | ChaosKind::Fail) && ev.replica == 0 {
                return Err("chaos may not drain/fail replica 0 (fleet would go dark)".to_string());
            }
        }
        if self.control_interval_s <= 0.0 {
            return Err("control_interval_s must be positive".to_string());
        }
        if self.horizon_s < 0.0 {
            return Err("horizon_s must be >= 0".to_string());
        }
        Ok(())
    }
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing/invalid number field '{key}'"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing/invalid string field '{key}'"))
}

fn req_bool(j: &Json, key: &str) -> Result<bool, String> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| format!("missing/invalid bool field '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_validates_and_round_trips() {
        let sc = Scenario::baseline();
        sc.validate().expect("baseline is valid");
        let s = sc.to_canonical_string();
        let back = Scenario::parse(&s).expect("canonical form parses");
        assert_eq!(back, sc);
        assert_eq!(back.to_canonical_string(), s, "round-trip is byte-stable");
    }

    #[test]
    fn invalid_scenarios_are_rejected() {
        let mut sc = Scenario::baseline();
        sc.router = "teleport".to_string();
        assert!(sc.validate().is_err());

        let mut sc = Scenario::baseline();
        sc.policies = vec!["not-a-policy!!".to_string()];
        assert!(sc.validate().is_err());

        let mut sc = Scenario::baseline();
        sc.replicas = 2;
        sc.chaos = vec![ChaosEvent {
            t_s: 1.0,
            kind: ChaosKind::Fail,
            replica: 0,
        }];
        assert!(sc.validate().is_err(), "failing replica 0 darkens the fleet");

        let mut sc = Scenario::baseline();
        sc.tenants = "2".to_string();
        assert!(sc.validate().is_err(), "registry without stamping is inert");
    }

    #[test]
    fn chaos_kind_names_round_trip() {
        for k in [
            ChaosKind::Drain,
            ChaosKind::Fail,
            ChaosKind::Rejoin,
            ChaosKind::ScaleUp,
        ] {
            assert_eq!(ChaosKind::parse(k.name()).unwrap(), k);
        }
        assert!(ChaosKind::parse("explode").is_err());
    }
}
