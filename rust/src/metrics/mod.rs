//! Serving metrics: per-request latency records, run-level aggregates, SLO
//! attainment (full + TTFT/TBT breakdown, paper Figs 3–4), token timelines
//! (Fig 5), traffic and energy summaries (Tables 2/7/8), streaming
//! sliding-window SLO/goodput over the live event stream ([`streaming`]),
//! and per-conversation-depth session tables ([`sessions`]).

pub mod sessions;
pub mod streaming;

pub use sessions::{depth_table, prefix_hits_by_request, DepthRow};
pub use streaming::{StreamingSlo, TenantSummary, WindowSummary};

use crate::config::slo::{evaluate, SloSpec};
use crate::moe::TrafficCounter;
use crate::simulator::energy::EnergyMeter;
use crate::util::stats::Samples;

/// Finalized latency record of one request.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival_s: f64,
    pub input_len: u32,
    pub output_len: u32,
    /// Time from arrival to first token (queue + prefill).
    pub ttft_s: f64,
    /// Inter-token gaps for tokens 2..N.
    pub tbts_s: Vec<f64>,
    pub finish_s: f64,
    /// Owning tenant ([`crate::tenant::TenantId`]; 0 = untenanted).
    pub tenant: u32,
}

impl RequestRecord {
    pub fn e2e_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// Aggregated outcome of one serving run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub requests: Vec<RequestRecord>,
    pub traffic: TrafficCounter,
    pub energy: EnergyMeter,
    /// Wall-clock span of the run (first arrival to last completion).
    pub makespan_s: f64,
    /// Engine time spent executing iterations (makespan minus idle gaps).
    pub busy_s: f64,
    /// Time-weighted mean decode batch size (Fig 3 dotted line).
    pub avg_decode_batch: f64,
    /// Iterations executed.
    pub iterations: u64,
    /// (time, cumulative tokens emitted) — global generation timeline.
    pub token_timeline: Vec<(f64, u64)>,
    /// Prompt tokens whose prefill was skipped via prefix-cache hits
    /// (`EngineEvent::PrefixHit` credit, summed).
    pub prefix_hit_tokens: u64,
    /// KV blocks that landed on this replica via cross-replica migration.
    pub migrated_blocks: u64,
    /// Prefill pauses issued by a preemption policy
    /// (`EngineEvent::Preempted` count; resumes are not re-counted).
    pub preemptions: u64,
}

/// SLO attainment split (paper Fig 4): full = both, plus per-component.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloSummary {
    pub full: f64,
    pub ttft_only: f64,
    pub tbt_only: f64,
    pub n: usize,
}

/// Per-tenant slice of a run: request counts, token volume, latency
/// percentiles, SLO attainment, and goodput (generated tokens of
/// SLO-attaining requests per second of makespan). Tenant 0 rows cover
/// untenanted traffic.
#[derive(Clone, Debug)]
pub struct TenantUsage {
    pub tenant: u32,
    pub n: usize,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_p99_s: f64,
    pub slo: SloSummary,
    /// Generated tokens of fully SLO-attaining requests / makespan.
    pub goodput_tok_s: f64,
}

impl RunMetrics {
    pub fn total_tokens(&self) -> u64 {
        self.requests
            .iter()
            .map(|r| (r.input_len + r.output_len) as u64)
            .sum()
    }

    pub fn generated_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_len as u64).sum()
    }

    pub fn ttft_samples(&self) -> Samples {
        let mut s = Samples::new();
        for r in &self.requests {
            s.push(r.ttft_s);
        }
        s
    }

    pub fn tbt_samples(&self) -> Samples {
        let mut s = Samples::new();
        for r in &self.requests {
            for &t in &r.tbts_s {
                s.push(t);
            }
        }
        s
    }

    pub fn e2e_samples(&self) -> Samples {
        let mut s = Samples::new();
        for r in &self.requests {
            s.push(r.e2e_s());
        }
        s
    }

    pub fn slo(&self, slo: &SloSpec) -> SloSummary {
        let mut full = 0usize;
        let mut ttft = 0usize;
        let mut tbt = 0usize;
        for r in &self.requests {
            let a = evaluate(r.ttft_s, &r.tbts_s, slo);
            full += a.full() as usize;
            ttft += a.ttft_ok as usize;
            tbt += a.tbt_ok as usize;
        }
        let n = self.requests.len().max(1);
        SloSummary {
            full: full as f64 / n as f64,
            ttft_only: ttft as f64 / n as f64,
            tbt_only: tbt as f64 / n as f64,
            n: self.requests.len(),
        }
    }

    /// Energy per (prompt + generated) token in mJ (paper Tables 2/8).
    pub fn energy_per_token_mj(&self) -> f64 {
        self.energy.per_token_mj(self.total_tokens())
    }

    /// Throughput in generated tokens/second over the makespan.
    pub fn gen_throughput(&self) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens() as f64 / self.makespan_s
    }

    /// Per-tenant usage/SLO table, ordered by tenant id (tenant 0 first
    /// when untenanted traffic is present). Empty when the run had no
    /// requests.
    pub fn per_tenant(&self, slo: &SloSpec) -> Vec<TenantUsage> {
        let mut by_tenant: std::collections::BTreeMap<u32, Vec<&RequestRecord>> =
            std::collections::BTreeMap::new();
        for r in &self.requests {
            by_tenant.entry(r.tenant).or_default().push(r);
        }
        by_tenant
            .into_iter()
            .map(|(tenant, recs)| {
                let mut ttft = Samples::new();
                let mut tbt = Samples::new();
                let mut full = 0usize;
                let mut ttft_ok = 0usize;
                let mut tbt_ok = 0usize;
                let mut input_tokens = 0u64;
                let mut output_tokens = 0u64;
                let mut good_tokens = 0u64;
                for r in &recs {
                    ttft.push(r.ttft_s);
                    for &t in &r.tbts_s {
                        tbt.push(t);
                    }
                    input_tokens += r.input_len as u64;
                    output_tokens += r.output_len as u64;
                    let a = evaluate(r.ttft_s, &r.tbts_s, slo);
                    full += a.full() as usize;
                    ttft_ok += a.ttft_ok as usize;
                    tbt_ok += a.tbt_ok as usize;
                    if a.full() {
                        good_tokens += r.output_len as u64;
                    }
                }
                let n = recs.len();
                let denom = n.max(1) as f64;
                TenantUsage {
                    tenant,
                    n,
                    input_tokens,
                    output_tokens,
                    ttft_p50_s: ttft.percentile(0.5),
                    ttft_p99_s: ttft.percentile(0.99),
                    tbt_p99_s: if tbt.is_empty() { 0.0 } else { tbt.percentile(0.99) },
                    slo: SloSummary {
                        full: full as f64 / denom,
                        ttft_only: ttft_ok as f64 / denom,
                        tbt_only: tbt_ok as f64 / denom,
                        n,
                    },
                    goodput_tok_s: if self.makespan_s > 0.0 {
                        good_tokens as f64 / self.makespan_s
                    } else {
                        0.0
                    },
                }
            })
            .collect()
    }

    /// Cumulative token timeline for one request (Fig 5).
    pub fn request_timeline(&self, id: u64, token_times: &[(u64, Vec<f64>)]) -> Vec<(f64, u64)> {
        token_times
            .iter()
            .find(|(rid, _)| *rid == id)
            .map(|(_, times)| {
                times
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| (t, i as u64 + 1))
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, ttft: f64, tbts: Vec<f64>) -> RequestRecord {
        let finish = ttft + tbts.iter().sum::<f64>();
        RequestRecord {
            id,
            arrival_s: 0.0,
            input_len: 100,
            output_len: tbts.len() as u32 + 1,
            ttft_s: ttft,
            tbts_s: tbts,
            finish_s: finish,
            tenant: 0,
        }
    }

    #[test]
    fn slo_breakdown_counts() {
        let mut m = RunMetrics::default();
        m.requests.push(rec(1, 0.5, vec![0.01; 5])); // both ok
        m.requests.push(rec(2, 9.0, vec![0.01; 5])); // ttft violation
        m.requests.push(rec(3, 0.5, vec![0.2; 5])); // tbt violation
        let slo = SloSpec {
            ttft_s: 5.0,
            tbt_s: 0.125,
        };
        let s = m.slo(&slo);
        assert!((s.full - 1.0 / 3.0).abs() < 1e-9);
        assert!((s.ttft_only - 2.0 / 3.0).abs() < 1e-9);
        assert!((s.tbt_only - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn e2e_is_ttft_plus_tbts() {
        let r = rec(1, 1.0, vec![0.1, 0.2]);
        assert!((r.e2e_s() - 1.3).abs() < 1e-12);
    }

    #[test]
    fn totals_and_throughput() {
        let mut m = RunMetrics::default();
        m.requests.push(rec(1, 0.5, vec![0.01; 9])); // output 10
        m.requests.push(rec(2, 0.5, vec![0.01; 4])); // output 5
        m.makespan_s = 5.0;
        assert_eq!(m.generated_tokens(), 15);
        assert_eq!(m.total_tokens(), 215);
        assert!((m.gen_throughput() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn per_tenant_groups_scores_and_goodput() {
        let mut m = RunMetrics::default();
        let mut a = rec(1, 0.5, vec![0.01; 5]); // tenant 1, attains
        a.tenant = 1;
        let mut b = rec(2, 9.0, vec![0.01; 5]); // tenant 2, TTFT violation
        b.tenant = 2;
        let mut c = rec(3, 0.5, vec![0.01; 5]); // tenant 2, attains
        c.tenant = 2;
        m.requests.push(a);
        m.requests.push(b);
        m.requests.push(c);
        m.makespan_s = 10.0;
        let slo = SloSpec {
            ttft_s: 5.0,
            tbt_s: 0.125,
        };
        let t = m.per_tenant(&slo);
        assert_eq!(t.len(), 2);
        assert_eq!((t[0].tenant, t[0].n), (1, 1));
        assert!((t[0].slo.full - 1.0).abs() < 1e-9);
        assert_eq!((t[1].tenant, t[1].n), (2, 2));
        assert!((t[1].slo.full - 0.5).abs() < 1e-9);
        assert!((t[1].slo.tbt_only - 1.0).abs() < 1e-9);
        // Only request 3 attains for tenant 2: 6 generated tokens / 10 s.
        assert!((t[1].goodput_tok_s - 0.6).abs() < 1e-9);
        assert!(t[1].ttft_p99_s > 8.9);
        assert_eq!(t[1].input_tokens, 200);
        assert_eq!(t[1].output_tokens, 12);
    }

    #[test]
    fn samples_extraction() {
        let mut m = RunMetrics::default();
        m.requests.push(rec(1, 1.0, vec![0.1, 0.3]));
        let mut tbt = m.tbt_samples();
        assert_eq!(tbt.len(), 2);
        assert!((tbt.percentile(1.0) - 0.3).abs() < 1e-12);
    }
}
