//! Streaming (sliding-window) serving metrics computed directly from the
//! typed [`EngineEvent`] stream — no end-of-run finalization step.
//!
//! [`RunMetrics`](crate::metrics::RunMetrics) answers "how did the run go"
//! after the run ends; an hours-long open-loop session needs "how is the
//! run going NOW". [`StreamingSlo`] is an [`EventSink`] that folds every
//! event into per-request state as it happens and keeps only a sliding
//! window of completions and token emissions, so memory is bounded by the
//! window, not the run. At any instant it reports a [`WindowSummary`]:
//! TTFT/TBT SLO attainment over the window's completions (Sarathi-style
//! per-request attainment: TTFT within SLO AND every token gap within
//! SLO), goodput (generated tokens of SLO-attaining completions per
//! second), and raw token throughput.
//!
//! The incremental computation is LOCKED against a post-hoc recomputation
//! from an [`EventLog`](crate::serve::EventLog) of the same run by
//! `tests/streaming_metrics.rs`: both derive TTFT and token gaps from the
//! same event timestamps with the same arithmetic, so the window summaries
//! bit-match.
//!
//! Retry semantics: if the control plane re-serves a request (spill
//! requeue or replica failure), its fresh `Arrived` RESETS the per-request
//! state — latency is judged on the attempt that actually completed, while
//! TTFT still counts from the request's original arrival stamp (carried in
//! the `Arrived` event's request). Tokens a dead replica streamed before a
//! failure stay in the throughput window (they were emitted) but never
//! count toward goodput (their request did not complete there).

use std::collections::BTreeMap;

use crate::config::slo::SloSpec;
use crate::serve::{EngineEvent, EventSink};
use crate::util::stats::Samples;

/// Sliding-window metrics at one evaluation instant `t_s`: the window
/// covers `(t_s - window_s, t_s]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WindowSummary {
    /// Evaluation instant (engine seconds).
    pub t_s: f64,
    /// Window length (engine seconds).
    pub window_s: f64,
    /// Requests that finished inside the window.
    pub completed: usize,
    /// Of those, how many attained the full SLO (TTFT and every TBT).
    pub attained: usize,
    /// Full-SLO attainment fraction (0.0 when the window is empty).
    pub slo_full: f64,
    /// TTFT-component attainment fraction (0.0 when the window is empty).
    pub slo_ttft: f64,
    /// TBT-component attainment fraction (0.0 when the window is empty).
    pub slo_tbt: f64,
    /// Generated tokens of SLO-attaining completions, per window second.
    pub goodput_tok_s: f64,
    /// Tokens emitted inside the window (first tokens + decode tokens).
    pub emitted: u64,
    /// Raw emission throughput over the window (`emitted / window_s`).
    pub throughput_tok_s: f64,
}

/// Per-tenant slice of one sliding window: attainment and goodput over
/// the window's completions owned by one tenant, plus the windowed TTFT
/// p99 (the noisy-neighbor isolation signal). Tenant 0 covers untenanted
/// traffic.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSummary {
    pub tenant: u32,
    /// Evaluation instant (engine seconds).
    pub t_s: f64,
    /// Window length (engine seconds).
    pub window_s: f64,
    /// This tenant's completions inside the window.
    pub completed: usize,
    /// Of those, how many attained the full SLO (TTFT and every TBT).
    pub attained: usize,
    pub slo_full: f64,
    pub slo_ttft: f64,
    pub slo_tbt: f64,
    /// Generated tokens of this tenant's SLO-attaining completions, per
    /// window second.
    pub goodput_tok_s: f64,
    /// p99 TTFT over this tenant's windowed completions (0.0 when empty).
    pub ttft_p99_s: f64,
}

/// In-flight per-request accumulator.
#[derive(Clone, Copy, Debug)]
struct PendingReq {
    arrival_s: f64,
    tenant: u32,
    ttft_s: Option<f64>,
    last_emit_s: f64,
    tbt_ok: bool,
    generated: u32,
}

/// One finished request, reduced to what window queries need.
#[derive(Clone, Copy, Debug)]
struct Completion {
    finish_s: f64,
    tenant: u32,
    /// TTFT of the completing attempt (original arrival to first token).
    ttft_s: f64,
    ttft_ok: bool,
    tbt_ok: bool,
    tokens: u32,
}

/// Sliding-window SLO/goodput sink over the engine event stream.
///
/// Feed it as a session sink (optionally sampling summaries every
/// `sample_every` seconds via [`StreamingSlo::with_samples`]), or query
/// [`StreamingSlo::summary_at`] at nondecreasing instants. Evicted history
/// never returns: query times must not go backwards.
pub struct StreamingSlo {
    slo: SloSpec,
    window_s: f64,
    pending: BTreeMap<u64, PendingReq>,
    /// Completions inside the current window, sorted by finish time.
    completions: Vec<Completion>,
    /// Token emission timestamps inside the current window, sorted.
    emissions: Vec<f64>,
    /// Latest event timestamp seen.
    watermark_s: f64,
    sample_dt: f64,
    next_sample_s: f64,
    samples: Vec<WindowSummary>,
}

impl StreamingSlo {
    pub fn new(slo: SloSpec, window_s: f64) -> Self {
        assert!(window_s > 0.0, "streaming window must be positive");
        StreamingSlo {
            slo,
            window_s,
            pending: BTreeMap::new(),
            completions: Vec::new(),
            emissions: Vec::new(),
            watermark_s: 0.0,
            sample_dt: 0.0,
            next_sample_s: 0.0,
            samples: Vec::new(),
        }
    }

    /// Record a [`WindowSummary`] every `dt_s` seconds of engine time,
    /// evaluated at the sample instant (events at exactly the instant are
    /// included; later events are not). Collect with
    /// [`StreamingSlo::samples`]; call [`StreamingSlo::flush_samples`]
    /// after the run for the trailing instants.
    pub fn with_samples(mut self, dt_s: f64) -> Self {
        assert!(dt_s > 0.0, "sample interval must be positive");
        self.sample_dt = dt_s;
        self.next_sample_s = dt_s;
        self
    }

    /// Summaries recorded so far (under `with_samples`).
    pub fn samples(&self) -> &[WindowSummary] {
        &self.samples
    }

    /// Latest event timestamp seen.
    pub fn watermark_s(&self) -> f64 {
        self.watermark_s
    }

    /// Record the remaining sample instants up to and including `end_s`.
    pub fn flush_samples(&mut self, end_s: f64) {
        if self.sample_dt <= 0.0 {
            return;
        }
        while self.next_sample_s <= end_s {
            let t = self.next_sample_s;
            let s = self.summary_at(t);
            self.samples.push(s);
            self.next_sample_s += self.sample_dt;
        }
    }

    /// The window summary at the current watermark.
    pub fn summary(&mut self) -> WindowSummary {
        self.summary_at(self.watermark_s)
    }

    /// The window summary at instant `t` (window `(t - window_s, t]`).
    /// Query instants must be nondecreasing across calls: evaluation
    /// evicts history older than `t - window_s` permanently.
    pub fn summary_at(&mut self, t: f64) -> WindowSummary {
        self.evict_before(t - self.window_s);

        // Entries past `t` (possible with out-of-order cross-replica
        // events) stay for a later query but do not count now.
        let n_compl = self.completions.partition_point(|c| c.finish_s <= t);
        let mut attained = 0usize;
        let mut ttft_okc = 0usize;
        let mut tbt_okc = 0usize;
        let mut good_tokens: u64 = 0;
        for c in &self.completions[..n_compl] {
            ttft_okc += c.ttft_ok as usize;
            tbt_okc += c.tbt_ok as usize;
            if c.ttft_ok && c.tbt_ok {
                attained += 1;
                good_tokens += c.tokens as u64;
            }
        }
        let emitted = self.emissions.partition_point(|&e| e <= t) as u64;
        let frac = |k: usize| {
            if n_compl == 0 {
                0.0
            } else {
                k as f64 / n_compl as f64
            }
        };
        WindowSummary {
            t_s: t,
            window_s: self.window_s,
            completed: n_compl,
            attained,
            slo_full: frac(attained),
            slo_ttft: frac(ttft_okc),
            slo_tbt: frac(tbt_okc),
            goodput_tok_s: good_tokens as f64 / self.window_s,
            emitted,
            throughput_tok_s: emitted as f64 / self.window_s,
        }
    }

    /// Per-tenant window summaries at instant `t`, ordered by tenant id.
    /// Same nondecreasing-instant contract as [`StreamingSlo::summary_at`].
    /// Tenants with no windowed completions are absent.
    pub fn tenant_summaries_at(&mut self, t: f64) -> Vec<TenantSummary> {
        self.evict_before(t - self.window_s);
        let n_compl = self.completions.partition_point(|c| c.finish_s <= t);
        // (completed, attained, ttft_ok, tbt_ok, good_tokens, ttfts)
        let mut by: BTreeMap<u32, (usize, usize, usize, usize, u64, Samples)> = BTreeMap::new();
        for c in &self.completions[..n_compl] {
            let e = by.entry(c.tenant).or_default();
            e.0 += 1;
            e.2 += c.ttft_ok as usize;
            e.3 += c.tbt_ok as usize;
            if c.ttft_ok && c.tbt_ok {
                e.1 += 1;
                e.4 += c.tokens as u64;
            }
            e.5.push(c.ttft_s);
        }
        by.into_iter()
            .map(
                |(tenant, (completed, attained, ttft_okc, tbt_okc, good_tokens, mut ttfts))| {
                    let denom = completed.max(1) as f64;
                    TenantSummary {
                        tenant,
                        t_s: t,
                        window_s: self.window_s,
                        completed,
                        attained,
                        slo_full: attained as f64 / denom,
                        slo_ttft: ttft_okc as f64 / denom,
                        slo_tbt: tbt_okc as f64 / denom,
                        goodput_tok_s: good_tokens as f64 / self.window_s,
                        ttft_p99_s: if ttfts.is_empty() {
                            0.0
                        } else {
                            ttfts.percentile(0.99)
                        },
                    }
                },
            )
            .collect()
    }

    /// One tenant's window summary at instant `t` (all-zero when the
    /// tenant has no windowed completions).
    pub fn tenant_summary_at(&mut self, tenant: u32, t: f64) -> TenantSummary {
        self.tenant_summaries_at(t)
            .into_iter()
            .find(|s| s.tenant == tenant)
            .unwrap_or(TenantSummary {
                tenant,
                t_s: t,
                window_s: self.window_s,
                completed: 0,
                attained: 0,
                slo_full: 0.0,
                slo_ttft: 0.0,
                slo_tbt: 0.0,
                goodput_tok_s: 0.0,
                ttft_p99_s: 0.0,
            })
    }

    /// Evict history at or before `lo` — it can never re-enter a later
    /// (nondecreasing) window.
    fn evict_before(&mut self, lo: f64) {
        let keep_from = self.completions.partition_point(|c| c.finish_s <= lo);
        self.completions.drain(..keep_from);
        let keep_from = self.emissions.partition_point(|&e| e <= lo);
        self.emissions.drain(..keep_from);
    }

    fn push_emission(&mut self, t: f64) {
        let pos = self.emissions.partition_point(|&e| e <= t);
        self.emissions.insert(pos, t);
    }
}

impl EventSink for StreamingSlo {
    fn on_event(&mut self, _replica: usize, ev: &EngineEvent) {
        let t = ev.t_s();
        // Sample instants are closed on the left: snapshot once the first
        // event STRICTLY past the instant arrives, so events at exactly
        // the instant are included.
        if self.sample_dt > 0.0 {
            while t > self.next_sample_s {
                let at = self.next_sample_s;
                let s = self.summary_at(at);
                self.samples.push(s);
                self.next_sample_s += self.sample_dt;
            }
        }
        if t > self.watermark_s {
            self.watermark_s = t;
        }
        match ev {
            EngineEvent::Arrived { req, .. } => {
                // A repeated Arrived (spill / failover retry) resets the
                // attempt; TTFT still counts from the original arrival.
                self.pending.insert(
                    req.id,
                    PendingReq {
                        arrival_s: req.arrival_s,
                        tenant: req.tenant,
                        ttft_s: None,
                        last_emit_s: 0.0,
                        tbt_ok: true,
                        generated: 0,
                    },
                );
            }
            EngineEvent::FirstToken { t_s, id } => {
                if let Some(p) = self.pending.get_mut(id) {
                    p.ttft_s = Some(t_s - p.arrival_s);
                    p.last_emit_s = *t_s;
                    p.generated = 1;
                    self.push_emission(*t_s);
                }
            }
            EngineEvent::TokenEmitted { t_s, id, generated } => {
                if let Some(p) = self.pending.get_mut(id) {
                    let gap = t_s - p.last_emit_s;
                    p.tbt_ok &= gap <= self.slo.tbt_s;
                    p.last_emit_s = *t_s;
                    p.generated = *generated;
                    self.push_emission(*t_s);
                }
            }
            EngineEvent::Finished { t_s, id } => {
                if let Some(p) = self.pending.remove(id) {
                    let c = Completion {
                        finish_s: *t_s,
                        tenant: p.tenant,
                        ttft_s: p.ttft_s.unwrap_or(f64::INFINITY),
                        ttft_ok: p.ttft_s.is_some_and(|x| x <= self.slo.ttft_s),
                        tbt_ok: p.tbt_ok,
                        tokens: p.generated,
                    };
                    let pos = self
                        .completions
                        .partition_point(|x| x.finish_s <= c.finish_s);
                    self.completions.insert(pos, c);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn slo() -> SloSpec {
        SloSpec {
            ttft_s: 1.0,
            tbt_s: 0.1,
        }
    }

    fn arrive(s: &mut StreamingSlo, id: u64, t: f64) {
        let req = Request {
            id,
            arrival_s: t,
            input_len: 100,
            output_len: 3,
            ..Default::default()
        };
        s.on_event(0, &EngineEvent::Arrived { t_s: t, req });
    }

    /// Serve one request: arrival, first token at `first`, then decode
    /// tokens at the given times, then finish at the last time.
    fn serve(s: &mut StreamingSlo, id: u64, arrival: f64, first: f64, decodes: &[f64]) {
        arrive(s, id, arrival);
        s.on_event(0, &EngineEvent::FirstToken { t_s: first, id });
        let mut gen = 1;
        for &t in decodes {
            gen += 1;
            s.on_event(
                0,
                &EngineEvent::TokenEmitted {
                    t_s: t,
                    id,
                    generated: gen,
                },
            );
        }
        let finish = decodes.last().copied().unwrap_or(first);
        s.on_event(0, &EngineEvent::Finished { t_s: finish, id });
    }

    #[test]
    fn empty_window_reports_zeroes() {
        let mut s = StreamingSlo::new(slo(), 2.0);
        let w = s.summary_at(5.0);
        assert_eq!(w.completed, 0);
        assert_eq!(w.attained, 0);
        assert_eq!(w.slo_full, 0.0);
        assert_eq!(w.goodput_tok_s, 0.0);
        assert_eq!(w.emitted, 0);
    }

    #[test]
    fn attainment_and_goodput_over_window() {
        let mut s = StreamingSlo::new(slo(), 10.0);
        // Request 1: TTFT 0.5 ok, gaps 0.05 ok -> attains; 3 tokens.
        serve(&mut s, 1, 0.0, 0.5, &[0.55, 0.6]);
        // Request 2: TTFT 2.0 (violates 1.0), gaps ok.
        serve(&mut s, 2, 0.0, 2.0, &[2.05, 2.1]);
        // Request 3: TTFT ok, one gap 0.2 violates 0.1.
        serve(&mut s, 3, 2.0, 2.5, &[2.7, 2.75]);
        let w = s.summary_at(3.0);
        assert_eq!(w.completed, 3);
        assert_eq!(w.attained, 1);
        assert_eq!(w.slo_full, 1.0 / 3.0);
        assert_eq!(w.slo_ttft, 2.0 / 3.0);
        assert_eq!(w.slo_tbt, 2.0 / 3.0);
        assert_eq!(w.emitted, 9);
        // Goodput counts only request 1's 3 tokens.
        assert_eq!(w.goodput_tok_s, 3.0 / 10.0);
        assert_eq!(w.throughput_tok_s, 9.0 / 10.0);
    }

    #[test]
    fn completions_slide_out_of_the_window() {
        let mut s = StreamingSlo::new(slo(), 1.0);
        serve(&mut s, 1, 0.0, 0.2, &[0.25, 0.3]); // finish 0.3
        serve(&mut s, 2, 2.0, 2.2, &[2.25, 2.3]); // finish 2.3
        let w = s.summary_at(2.5);
        assert_eq!(w.completed, 1, "only the recent completion remains");
        assert_eq!(w.emitted, 3);
        // Far future: everything slid out, zero-completion window.
        let w = s.summary_at(10.0);
        assert_eq!(w.completed, 0);
        assert_eq!(w.slo_full, 0.0);
        assert_eq!(w.emitted, 0);
    }

    #[test]
    fn retry_resets_the_attempt_but_keeps_original_arrival() {
        let mut s = StreamingSlo::new(slo(), 100.0);
        // First attempt on replica 0 dies mid-decode.
        arrive(&mut s, 1, 0.0);
        s.on_event(0, &EngineEvent::FirstToken { t_s: 0.3, id: 1 });
        s.on_event(
            0,
            &EngineEvent::TokenEmitted {
                t_s: 0.35,
                id: 1,
                generated: 2,
            },
        );
        // Retry on replica 1 (same original arrival stamp), completing.
        s.on_event(
            1,
            &EngineEvent::Arrived {
                t_s: 1.0,
                req: Request {
                    id: 1,
                    arrival_s: 0.0,
                    input_len: 100,
                    output_len: 3,
                    ..Default::default()
                },
            },
        );
        s.on_event(1, &EngineEvent::FirstToken { t_s: 1.6, id: 1 });
        s.on_event(
            1,
            &EngineEvent::TokenEmitted {
                t_s: 1.65,
                id: 1,
                generated: 2,
            },
        );
        s.on_event(
            1,
            &EngineEvent::TokenEmitted {
                t_s: 1.7,
                id: 1,
                generated: 3,
            },
        );
        s.on_event(1, &EngineEvent::Finished { t_s: 1.7, id: 1 });
        let w = s.summary();
        assert_eq!(w.completed, 1);
        // TTFT of the completing attempt = 1.6 - 0.0 (original arrival):
        // violates the 1.0 s SLO even though the retry's own queueing was
        // short — the client waited since t=0.
        assert_eq!(w.attained, 0);
        assert_eq!(w.slo_tbt, 1.0, "retry gaps were all within SLO");
        // Both attempts' emissions count toward raw throughput.
        assert_eq!(w.emitted, 5);
    }

    /// Like `serve`, but the request belongs to `tenant`.
    fn serve_tenant(
        s: &mut StreamingSlo,
        id: u64,
        tenant: u32,
        arrival: f64,
        first: f64,
        decodes: &[f64],
    ) {
        let req = Request {
            id,
            arrival_s: arrival,
            input_len: 100,
            output_len: decodes.len() as u32 + 1,
            tenant,
            ..Default::default()
        };
        s.on_event(0, &EngineEvent::Arrived { t_s: arrival, req });
        s.on_event(0, &EngineEvent::FirstToken { t_s: first, id });
        let mut gen = 1;
        for &t in decodes {
            gen += 1;
            s.on_event(
                0,
                &EngineEvent::TokenEmitted {
                    t_s: t,
                    id,
                    generated: gen,
                },
            );
        }
        let finish = decodes.last().copied().unwrap_or(first);
        s.on_event(0, &EngineEvent::Finished { t_s: finish, id });
    }

    #[test]
    fn tenant_windows_split_attainment_goodput_and_p99() {
        let mut s = StreamingSlo::new(slo(), 10.0);
        serve_tenant(&mut s, 1, 1, 0.0, 0.5, &[0.55, 0.6]); // t1 attains
        serve_tenant(&mut s, 2, 2, 0.0, 2.0, &[2.05, 2.1]); // t2 TTFT viol.
        serve_tenant(&mut s, 3, 2, 0.0, 0.4, &[0.45, 0.5]); // t2 attains
        let by = s.tenant_summaries_at(3.0);
        assert_eq!(by.len(), 2);
        assert_eq!((by[0].tenant, by[0].completed, by[0].attained), (1, 1, 1));
        assert_eq!(by[0].slo_full, 1.0);
        assert_eq!(by[0].goodput_tok_s, 3.0 / 10.0);
        assert!((by[0].ttft_p99_s - 0.5).abs() < 1e-12);
        assert_eq!((by[1].tenant, by[1].completed, by[1].attained), (2, 2, 1));
        assert_eq!(by[1].slo_full, 0.5);
        assert_eq!(by[1].slo_ttft, 0.5);
        assert_eq!(by[1].slo_tbt, 1.0);
        assert!(by[1].ttft_p99_s > 1.9, "p99 tracks the slow completion");
        // Absent tenant reports an all-zero window.
        let none = s.tenant_summary_at(7, 3.0);
        assert_eq!((none.completed, none.attained), (0, 0));
        assert_eq!(none.ttft_p99_s, 0.0);
        // The global window is the union of the tenant slices.
        let w = s.summary_at(3.0);
        assert_eq!(w.completed, 3);
        assert_eq!(w.attained, 2);
    }

    #[test]
    fn sampling_snapshots_at_fixed_instants() {
        let mut s = StreamingSlo::new(slo(), 1.0).with_samples(1.0);
        serve(&mut s, 1, 0.0, 0.4, &[0.45, 0.5]); // finish 0.5
        serve(&mut s, 2, 1.2, 1.6, &[1.65, 1.7]); // finish 1.7
        // The event at 1.2 crossed the t=1.0 instant: one sample so far.
        assert_eq!(s.samples().len(), 1);
        assert_eq!(s.samples()[0].t_s, 1.0);
        assert_eq!(s.samples()[0].completed, 1);
        s.flush_samples(2.0);
        assert_eq!(s.samples().len(), 2);
        assert_eq!(s.samples()[1].t_s, 2.0);
        assert_eq!(s.samples()[1].completed, 1, "req 1 slid out, req 2 in");
    }
}
