//! Per-session metrics: how multi-turn conversations behave as they
//! deepen — TTFT, prefix-cache payoff, and SLO attainment grouped by
//! conversation depth (main-chain turn number; tool-call children and
//! joins inherit the depth of the turn that spawned them).
//!
//! The grouping is decoupled from the session layer on purpose: the run
//! produces plain [`RequestRecord`]s and `PrefixHit` credits, and a
//! [`SessionProbe`](crate::workload::SessionProbe) (or any other id →
//! depth oracle) supplies the lineage. That keeps this module a pure
//! function of run outputs, usable from tests, the CLI, and reports
//! without re-running anything.

use std::collections::BTreeMap;

use crate::config::slo::{evaluate, SloSpec};
use crate::metrics::RequestRecord;
use crate::util::stats::Samples;

/// One depth bucket of a session run: all turns whose conversation depth
/// is `depth`, across every session in the run.
#[derive(Clone, Debug, PartialEq)]
pub struct DepthRow {
    /// Main-chain turn number, 1-based (turn 1 = the opening prompt).
    pub depth: u32,
    /// Requests in this bucket.
    pub n: usize,
    pub ttft_mean_s: f64,
    pub ttft_p99_s: f64,
    /// Prompt tokens this bucket skipped via prefix-cache hits
    /// (`EngineEvent::PrefixHit` credit, summed). Grows with depth when
    /// cross-turn caching works: deeper turns re-claim everything their
    /// ancestors published.
    pub prefix_hit_tokens: u64,
    /// Fraction of the bucket attaining the full SLO.
    pub slo_full: f64,
}

/// Group finished requests by conversation depth.
///
/// * `records` — the run's per-request latency records.
/// * `hits` — prefix-cache credit per request id (cached tokens from
///   `EngineEvent::PrefixHit`; requests without a hit are simply absent).
/// * `depth_of` — id → depth oracle; `None` excludes the request (e.g.
///   background open-loop traffic mixed into a session run).
/// * `slo` — the SLO to score each bucket against.
///
/// Rows come back ordered by depth. Requests the oracle does not know are
/// left out of every bucket, so a mixed workload reports only its
/// session slice.
pub fn depth_table(
    records: &[RequestRecord],
    hits: &BTreeMap<u64, u64>,
    depth_of: impl Fn(u64) -> Option<u32>,
    slo: &SloSpec,
) -> Vec<DepthRow> {
    let mut buckets: BTreeMap<u32, Vec<&RequestRecord>> = BTreeMap::new();
    for r in records {
        if let Some(d) = depth_of(r.id) {
            buckets.entry(d).or_default().push(r);
        }
    }
    buckets
        .into_iter()
        .map(|(depth, recs)| {
            let mut ttft = Samples::new();
            let mut full = 0usize;
            let mut hit_tokens = 0u64;
            for r in &recs {
                ttft.push(r.ttft_s);
                full += evaluate(r.ttft_s, &r.tbts_s, slo).full() as usize;
                hit_tokens += hits.get(&r.id).copied().unwrap_or(0);
            }
            let n = recs.len();
            DepthRow {
                depth,
                n,
                ttft_mean_s: ttft.mean(),
                ttft_p99_s: ttft.percentile(0.99),
                prefix_hit_tokens: hit_tokens,
                slo_full: full as f64 / n.max(1) as f64,
            }
        })
        .collect()
}

/// Collect per-request prefix-cache credit from an event stream's
/// `PrefixHit` events, in the shape [`depth_table`] consumes. Accepts
/// any borrowed event iterator, e.g.
/// `log.events.iter().map(|(_, e)| e)` over an
/// [`EventLog`](crate::serve::EventLog).
pub fn prefix_hits_by_request<'a>(
    events: impl IntoIterator<Item = &'a crate::serve::EngineEvent>,
) -> BTreeMap<u64, u64> {
    let mut hits = BTreeMap::new();
    for ev in events {
        if let crate::serve::EngineEvent::PrefixHit {
            id, cached_tokens, ..
        } = ev
        {
            *hits.entry(*id).or_insert(0) += *cached_tokens as u64;
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::EngineEvent;

    fn rec(id: u64, ttft: f64) -> RequestRecord {
        RequestRecord {
            id,
            arrival_s: 0.0,
            input_len: 100,
            output_len: 10,
            ttft_s: ttft,
            tbts_s: vec![0.01; 9],
            finish_s: ttft + 0.09,
            tenant: 0,
        }
    }

    fn slo() -> SloSpec {
        SloSpec {
            ttft_s: 1.0,
            tbt_s: 0.125,
        }
    }

    #[test]
    fn buckets_by_depth_and_sums_hits() {
        let records = vec![rec(1, 2.0), rec(2, 0.5), rec(3, 0.25), rec(4, 0.25)];
        let mut hits = BTreeMap::new();
        hits.insert(2u64, 64u64);
        hits.insert(3u64, 128u64);
        // ids 1-2 are depth 1, id 3 depth 2; id 4 is foreign traffic.
        let depth_of = |id: u64| match id {
            1 | 2 => Some(1),
            3 => Some(2),
            _ => None,
        };
        let rows = depth_table(&records, &hits, depth_of, &slo());
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].depth, rows[0].n), (1, 2));
        assert!((rows[0].ttft_mean_s - 1.25).abs() < 1e-9);
        assert_eq!(rows[0].prefix_hit_tokens, 64);
        assert!((rows[0].slo_full - 0.5).abs() < 1e-9); // id 1 misses TTFT
        assert_eq!((rows[1].depth, rows[1].n), (2, 1));
        assert_eq!(rows[1].prefix_hit_tokens, 128);
        assert!((rows[1].slo_full - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_requests_are_excluded_entirely() {
        let records = vec![rec(9, 0.1)];
        let rows = depth_table(&records, &BTreeMap::new(), |_| None, &slo());
        assert!(rows.is_empty());
    }

    #[test]
    fn prefix_hits_accumulate_per_request() {
        let events = vec![
            EngineEvent::PrefixHit {
                t_s: 0.0,
                id: 7,
                cached_tokens: 32,
            },
            EngineEvent::PrefixHit {
                t_s: 1.0,
                id: 7,
                cached_tokens: 16,
            },
            EngineEvent::TokenEmitted {
                t_s: 1.5,
                id: 7,
                generated: 1,
            },
            EngineEvent::PrefixHit {
                t_s: 2.0,
                id: 8,
                cached_tokens: 8,
            },
        ];
        let hits = prefix_hits_by_request(&events);
        assert_eq!(hits.get(&7), Some(&48));
        assert_eq!(hits.get(&8), Some(&8));
        assert_eq!(hits.len(), 2);
    }
}
